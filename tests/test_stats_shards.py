"""Lock-sharded StatsBoard: thread-affine write stripes, merged read folds."""
import threading

import pytest

from repro.core.stats import PredicateStats, ShardedPredicateStats, StatsBoard


def test_single_shard_board_keeps_raw_entries():
    # shards=1 (the default, and always the case under SimClock) must keep
    # the original PredicateStats entries bit-for-bit — deterministic
    # benchmarks and tests seed their fields directly
    b = StatsBoard(["a"], shards=1)
    assert isinstance(b["a"], PredicateStats)
    assert not isinstance(b["a"], ShardedPredicateStats)


def test_sharded_board_entries_and_stripe_access():
    b = StatsBoard(["a"], shards=3)
    assert isinstance(b["a"], ShardedPredicateStats)
    assert len(b["a"].stripes) == 3
    # ensure(name, shard=i) hands back that shard's raw write stripe
    assert b.ensure("a", shard=1) is b["a"].stripe(1)


def test_merged_counters_sum_across_stripes():
    b = StatsBoard(["a"], shards=2)
    b["a"].stripe(0).record_eval(10, 4, 0.01)
    b["a"].stripe(1).record_eval(30, 15, 0.03)
    m = b["a"]
    assert m.batches == 2
    assert m.tickets == 40
    assert m.wins == (10 - 4) + (30 - 15)
    # lottery selectivity folds tickets/wins globally: 1 - 21/40
    assert m.selectivity() == pytest.approx(1.0 - 21 / 40)
    assert m.measured


def test_merged_cost_is_batch_weighted_fold():
    b = StatsBoard(["a"], shards=2, cost_alpha=1.0)  # EMA == last sample
    s0, s1 = b["a"].stripe(0), b["a"].stripe(1)
    s0.record_eval(10, 10, 0.10)   # 0.010 s/row, 1 batch
    s1.record_eval(10, 10, 0.40)   # 0.040 s/row
    s1.record_eval(10, 10, 0.40)   # ... over 2 batches
    want = (0.010 * 1 + 0.040 * 2) / 3
    assert b["a"].cost() == pytest.approx(want)


def test_merged_cost_ignores_unmeasured_stripes():
    b = StatsBoard(["a"], shards=4, cost_alpha=1.0)
    b["a"].stripe(2).record_eval(10, 10, 0.20)
    assert b["a"].cost() == pytest.approx(0.020)  # not dragged toward 0
    assert StatsBoard(["z"], shards=4)["z"].cost(default=7.0) == 7.0


def test_merged_bucket_selectivity_folds_stripes():
    b = StatsBoard(["a"], shards=2)
    # bucket 5: 30 tickets / 12 wins split across the two stripes
    b["a"].stripe(0).record_eval(10, 6, 0.01, bucket=5)
    b["a"].stripe(1).record_eval(20, 12, 0.01, bucket=5)
    sel = b["a"].selectivity(bucket=5, min_bucket_tickets=20)
    assert sel == pytest.approx(1.0 - 12 / 30)
    # below the ticket floor the global fold is used instead
    sel_floor = b["a"].selectivity(bucket=5, min_bucket_tickets=100)
    assert sel_floor == pytest.approx(1.0 - 12 / 30)  # global == bucket here


def test_merged_cache_hit_rate_and_snapshot():
    b = StatsBoard(["a"], shards=2)
    b["a"].stripe(0).record_cache(10, 5)
    b["a"].stripe(1).record_cache(30, 6)
    assert b["a"].cache_hit_rate() == pytest.approx(11 / 40)
    b["a"].stripe(0).record_eval(10, 5, 0.01)
    merged = b.snapshot()["a"]
    assert merged["batches"] == 1
    # per-stripe observability: shard 1 recorded no evals
    assert b.snapshot(shard=1)["a"]["batches"] == 0
    assert b.snapshot(shard=0)["a"]["batches"] == 1


def test_thread_affine_recording_lands_on_one_stripe_per_thread():
    b = StatsBoard(["a"], shards=4)
    done = []

    def rec():
        for _ in range(50):
            b["a"].record_eval(1, 1, 0.001)
        done.append(threading.get_ident() % 4)

    threads = [threading.Thread(target=rec) for _ in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    # every thread's 50 recordings landed on exactly its affine stripe
    per_stripe = [s.batches for s in b["a"].stripes]
    assert sum(per_stripe) == 150
    for stripe_idx in done:
        assert per_stripe[stripe_idx] % 50 == 0
        assert per_stripe[stripe_idx] > 0


def test_all_measured_uses_merged_view():
    b = StatsBoard(["a", "b"], shards=2)
    b["a"].stripe(0).record_eval(5, 5, 0.01)
    assert not b.all_measured()
    b["b"].stripe(1).record_eval(5, 5, 0.01)
    assert b.all_measured()  # one stripe each suffices for the warmup gate


def test_load_ledger_striped_but_consistent():
    b = StatsBoard(["a"], shards=4)

    def churn(wid):
        for _ in range(200):
            b.add_load(wid, 2.0)
            b.finish_load(wid, 2.0)

    threads = [threading.Thread(target=churn, args=(f"w{i}",)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=10)
    assert all(b.load_of(f"w{i}") == 0.0 for i in range(4))
