"""Multi-device dry-run smoke via subprocess (the 512-device flag must not
leak into this test process). Uses a small 16-device mesh + the smallest
arch so the test stays fast; the full 256/512-chip matrix is the
launch/dryrun.py deliverable (results/dryrun/, EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.configs import get_config, get_shape
from repro.launch.dryrun import lower_cell
from repro.roofline import analysis

mesh = jax.make_mesh((4, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = get_config("smollm-135m")
import dataclasses
cfg = dataclasses.replace(cfg, num_layers=4)
shape = dataclasses.replace(get_shape("train_4k"), global_batch=16, seq_len=1024)
lowered = lower_cell(cfg, shape, mesh)
compiled = lowered.compile()
ma = compiled.memory_analysis()
colls = analysis.parse_collectives(compiled.as_text(), 16)
print(json.dumps({
    "temp": ma.temp_size_in_bytes,
    "flops": compiled.cost_analysis().get("flops", 0.0),
    "n_allreduce": colls["all-reduce"]["count"],
    "wire": analysis.total_wire_bytes(colls),
}))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["n_allreduce"] > 0     # DP gradient reduction exists
    assert rec["wire"] > 0


@pytest.mark.slow
def test_decode_seqsharded_subprocess():
    """Sequence-sharded decode lowers AND produces correct logits on a real
    4-device mesh (partial-softmax combine vs single-device reference)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import model_api
from repro.models.layers import ShardCtx
from repro.distributed.sharding import SERVE_RULES, tree_shape_dtypes

cfg = get_config("smollm-135m").reduce_for_smoke()
api = model_api(cfg)
params = api.init_params(cfg, jax.random.key(0))
B, S = 4, 32
toks = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
cache, _ = api.prefill(cfg, params, {"tokens": toks}, pad_cache_to=S + 4)
ref_cache = jax.tree.map(lambda x: x, cache)
_, ref_logits = api.decode_step(cfg, params, ref_cache, {"token": toks[:, -1]})

mesh = jax.make_mesh((1, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ShardCtx(mesh, SERVE_RULES)
from repro.distributed.sharding import named_sharding
def place(x, logical):
    return jax.device_put(x, named_sharding(x.shape, logical, SERVE_RULES, mesh))
pcache = {
    "k": place(cache["k"], "layers batch cache_seq kv_heads ."),
    "v": place(cache["v"], "layers batch cache_seq kv_heads ."),
    "lengths": place(cache["lengths"], "batch"),
}
with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
    _, sharded_logits = jax.jit(
        lambda p, c, b: api.decode_step(cfg, p, c, b, ctx)
    )(params, pcache, {"token": toks[:, -1]})
np.testing.assert_allclose(
    np.asarray(ref_logits, np.float32), np.asarray(sharded_logits, np.float32),
    rtol=2e-3, atol=2e-3,
)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=600, cwd=ROOT,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
