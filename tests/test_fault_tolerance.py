"""Straggler watchdog + heartbeat + failure injector unit tests."""
import os

from repro.distributed.fault_tolerance import (
    FailureInjector, Heartbeat, StepWatchdog,
)


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(k=5.0, min_samples=5)
    events = []
    wd.on_straggler = events.append
    for _ in range(20):
        wd.observe(0.100)
    ev = wd.observe(1.0)  # 10x slower
    assert ev is not None and ev.seconds == 1.0
    assert events and events[0].threshold < 1.0


def test_watchdog_tolerates_noise():
    import random

    random.seed(0)
    wd = StepWatchdog(k=6.0)
    for _ in range(100):
        assert wd.observe(0.1 + random.uniform(-0.005, 0.005)) is None


def test_watchdog_window_adapts():
    """After a regime change (persistently slower), the envelope adapts:
    flags fire during the transition, then stop once the window turns over."""
    wd = StepWatchdog(k=5.0, window=20)
    for _ in range(20):
        wd.observe(0.1)
    flags = [wd.observe(0.3) is not None for _ in range(40)]
    assert any(flags[:20])          # transition is flagged
    assert not any(flags[20:])      # adapted after a full window


def test_failure_injector_fires_once():
    inj = FailureInjector([3])
    inj.check(1); inj.check(2)
    import pytest

    with pytest.raises(RuntimeError):
        inj.check(3)
    inj.check(3)  # second pass: already consumed
    assert inj.failures == 1


def test_heartbeat(tmp_path):
    hb = Heartbeat(os.path.join(tmp_path, "hb"))
    hb.beat(42)
    content = open(os.path.join(tmp_path, "hb")).read()
    assert content.startswith("42 ")
