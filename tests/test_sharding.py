"""Sharding rules: divisibility-aware spec resolution, batch axes, submesh
carving for Laminar device allocation."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
from jax.sharding import Mesh, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS  # noqa: E402
from repro.distributed.meshes import cost_shares, split_mesh_data_axis  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    SERVE_RULES, TRAIN_RULES, parse_dims, spec_for,
)
from repro.models.registry import model_api  # noqa: E402


class FakeMesh:
    """Duck-typed mesh: spec_for only reads axis_names + devices.shape."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


MESH = FakeMesh((16, 16), ("data", "model"))
POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_parse_dims():
    assert parse_dims("layers d_model_w d_ff") == ("layers", "d_model_w", "d_ff")
    assert parse_dims("batch . d_model") == ("batch", None, "d_model")
    assert parse_dims("") == ()


def test_divisible_dims_shard():
    spec = spec_for((4096, 11008), "d_model_w d_ff", TRAIN_RULES, MESH)
    assert spec == P("data", "model")


def test_indivisible_dims_replicate():
    # yi-6b kv=4 over a 16-way model axis -> replicated
    spec = spec_for((4096, 4, 128), "d_model_w kv_heads .", TRAIN_RULES, MESH)
    assert spec == P("data", None, None)


def test_axis_claimed_once():
    # experts claims 'model'; d_ff then falls back to replicated
    spec = spec_for((35, 128, 7168, 4864), "layers experts expert_dw d_ff",
                    TRAIN_RULES, MESH)
    assert spec == P(None, "model", "data", None)
    # grok: 8 experts do NOT divide 16 -> d_ff gets 'model' instead
    spec2 = spec_for((64, 8, 6144, 32768), "layers experts expert_dw d_ff",
                     TRAIN_RULES, MESH)
    assert spec2 == P(None, None, "data", "model")


def test_batch_axes_multipod():
    spec = spec_for((256, 4096), "batch seq", TRAIN_RULES, POD)
    assert spec == P(("pod", "data"), None)
    spec1 = spec_for((256, 4096), "batch seq", TRAIN_RULES, MESH)
    assert spec1 == P("data", None)


def test_serve_rules_no_fsdp_for_dense():
    assert spec_for((4096, 14336), "d_model_w d_ff", SERVE_RULES, MESH) == \
        P(None, "model")


def test_decode_cache_seq_sharded():
    spec = spec_for((32, 128, 32768, 8, 128),
                    "layers batch cache_seq kv_heads .", SERVE_RULES, MESH)
    assert spec == P(None, "data", "model", None, None)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("rules", [TRAIN_RULES, SERVE_RULES])
def test_all_arch_param_specs_resolve(arch, rules):
    """Every param leaf of every FULL config resolves to a valid spec with
    no axis used twice and all sharded dims divisible."""
    cfg = ARCHS[arch]
    api = model_api(cfg)
    shapes, logical = api.param_shapes(cfg), api.param_logical(cfg)
    flat_s = jax.tree.leaves(shapes)
    flat_l = jax.tree.leaves(logical)
    assert len(flat_s) == len(flat_l)
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    for sds, logical_dims in zip(flat_s, flat_l):
        spec = spec_for(sds.shape, logical_dims, rules, MESH)
        used = []
        for dim, ax in zip(sds.shape, spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            denom = int(np.prod([sizes[a] for a in axes]))
            assert dim % denom == 0, (arch, sds.shape, spec)
            used.extend(axes)
        assert len(used) == len(set(used)), (arch, sds.shape, spec)


def test_split_mesh_data_axis():
    devs = np.arange(16).reshape(8, 2)
    mesh = Mesh(np.asarray(jax.devices() * 16).reshape(8, 2)[:8, :2]
                if len(jax.devices()) >= 1 else devs, ("data", "model"))
    # use the real 1-device mesh trick: replicate device object
    shares = cost_shares({"a": 3.0, "b": 1.0})
    subs = split_mesh_data_axis(mesh, shares)
    assert set(subs) == {"a", "b"}
    na = subs["a"].devices.shape[0]
    nb = subs["b"].devices.shape[0]
    assert na + nb == 8 and na > nb >= 1
