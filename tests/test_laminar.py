"""Laminar router: GACU lazy activation, backpressure scaling, data-aware
load balancing, device-alternating placement (§5)."""
import time

import numpy as np

from repro.core import (
    AQPExecutor, CostDriven, DataAware, DeviceAlternating, Predicate,
    RoundRobin, SimClock, UDF, make_batch,
)


def _pred(name, *, sleep=0.0, cost=None, resource="cpu", proxy=None):
    def fn(d):
        if sleep:
            time.sleep(sleep)
        return np.ones(len(d["x"]), bool)

    udf = UDF(name + "_udf", fn=fn, columns=("x",), resource=resource,
              cost_model=cost, proxy_cost=proxy)
    return Predicate(name, udf, compare=lambda o: o.astype(bool))


def _batches(n, per=10, widths=None):
    out = []
    for i in range(0, n, per):
        w = widths[i // per] if widths is not None else 4
        out.append(make_batch({"x": np.ones((per, w))}, np.arange(i, i + per)))
    return out


def test_gacu_lazy_activation():
    """Contexts are pre-created (greedy) but only activate when routed to
    (conservative): a fast predicate should not wake all 50 workers."""
    p = _pred("p")
    ex = AQPExecutor([p], max_workers=50, warmup=False)
    ex.collect(iter(_batches(50)))
    lam = ex.laminars["p"]
    assert len(lam.workers) == 50                 # greedy allocation
    active = sum(1 for w in lam.workers if w.activated)
    assert 1 <= active < 50                       # conservative use


def test_gacu_warm_fn_called_once_per_activated_worker():
    calls = []

    def warm():
        calls.append(1)

    udf = UDF("u", fn=lambda d: np.ones(len(d["x"]), bool), columns=("x",),
              warm_fn=warm)
    p = Predicate("p", udf, compare=lambda o: o)
    ex = AQPExecutor([p], max_workers=4, warmup=False)
    ex.collect(iter(_batches(40)))
    # lazy init happens on first routed batch; shared UDF warms once
    assert len(calls) == 1


def test_scale_up_under_backpressure():
    """Slow predicate + many batches -> the router activates more workers."""
    p = _pred("p", sleep=0.01)
    ex = AQPExecutor([p], max_workers=8, warmup=False)
    ex.collect(iter(_batches(200)))
    assert ex.active_worker_counts()["p"] >= 2


def test_data_aware_beats_round_robin_fig14():
    """UC4 reproduction: heavy-tailed batch costs -> data-aware load
    balancing yields a shorter simulated makespan than round-robin.

    Review length is encoded as ROW COUNT so it drives both the simulated
    cost and the data-aware proxy (input size) — 'longer reviews cost more'.
    """
    def run(policy_factory, seed):
        rng = np.random.default_rng(seed)
        widths = np.clip(rng.lognormal(2.0, 1.0, 40), 1, 200).astype(int)
        clk = SimClock()
        udf = UDF("llm_udf", fn=lambda d: np.ones(len(d["x"]), bool),
                  columns=("x",), cost_model=lambda rows: float(rows),
                  bucket=False)
        p = Predicate("llm", udf, compare=lambda o: o.astype(bool))
        ex = AQPExecutor([p], clock=clk, warmup=False, max_workers=4,
                         laminar_policy_factory=policy_factory)
        batches = [
            make_batch({"x": np.ones((int(w), 1))},
                       np.arange(i * 1000, i * 1000 + int(w)))
            for i, w in enumerate(widths)
        ]
        n_rows = sum(int(w) for w in widths)
        got = sum(b.rows for b in ex.run(iter(batches)))
        assert got == n_rows
        return ex.makespan

    # the paper reports medians of repeated runs (pipeline queues randomize
    # order); do the same — single runs have scheduler-startup variance
    t_rr = np.median([run(RoundRobin, s) for s in (1, 2, 3)])
    t_da = np.median([run(DataAware, s) for s in (1, 2, 3)])
    assert t_da < t_rr * 0.9, f"expected >10% win, got {t_rr/t_da:.3f}x"


def test_device_alternating_spreads_devices():
    clk = SimClock()
    udf = UDF("u", fn=lambda d: np.ones(len(d["x"]), bool), columns=("x",),
              cost_model=lambda rows: 0.01 * rows)
    p = Predicate("p", udf, compare=lambda o: o.astype(bool))
    ex = AQPExecutor(
        [p], clock=clk, warmup=False, max_workers=4,
        laminar_policy_factory=DeviceAlternating,
        devices={"p": ("tpu:0", "tpu:1")},
    )
    ex.collect(iter(_batches(100)))
    groups = {w.device_group for w in ex.laminars["p"].workers if w.activated}
    assert groups == {"tpu:0", "tpu:1"}
