"""QueryService (launch/serve.py): the always-on multi-tenant layer —
admission control, priority/deadline dispatch, cancellation, name-conflict
serialization, the cross-query live-prior channel, per-query QueryReport
telemetry, and the ``_service`` snapshot key contract."""
import threading
import time
from collections import Counter

import numpy as np
import pytest

from repro.core import AQPExecutor, Predicate, UDF, make_batch
from repro.core.statstore import StatsStore, fingerprint_of
from repro.launch.serve import (
    AdmissionError,
    QueryHandle,
    QueryReport,
    QueryService,
)


def _pred(name, *, keep_mod=2, sleep=0.0, fingerprint=None):
    """Keeps rows whose id is NOT divisible by ``keep_mod``."""

    def fn(d):
        if sleep:
            time.sleep(sleep)
        return d["x"].astype(np.int64) % keep_mod != 0

    udf = UDF(name + "_udf", fn=fn, columns=("x",), bucket=False,
              fingerprint=fingerprint)
    return Predicate(name, udf, compare=lambda o: o.astype(bool))


def _batches(ids, per=8):
    ids = np.asarray(ids, np.int64)
    return [make_batch({"x": ids[i:i + per].astype(np.float64)},
                       ids[i:i + per])
            for i in range(0, len(ids), per)]


def _expected(ids, keep_mod):
    return Counter(int(i) for i in ids if i % keep_mod != 0)


_EXEC_KW = dict(max_workers=2, warmup=False)


# --------------------------------------------------------------------------- #
# Submit / await / report
# --------------------------------------------------------------------------- #
def test_submit_and_result_exact_multiset():
    ids = np.arange(64)
    with QueryService(max_concurrent=2) as svc:
        h = svc.submit([_pred("p0", keep_mod=3)], iter(_batches(ids)),
                       **_EXEC_KW)
        rep = h.result(timeout=30)
    assert rep.state == "DONE" and h.done()
    assert Counter(map(int, rep.row_ids)) == _expected(ids, 3)
    assert rep.rows == sum(_expected(ids, 3).values())
    assert rep.batches == len(h.output)
    assert rep.queue_time_s >= 0 and rep.eval_time_s > 0
    assert rep.deadline_met is None            # no deadline given
    assert rep.board_predicates == ("p0",)     # only its OWN predicate
    assert "p0" in rep.cache_hit_rates
    assert rep.routing and rep.reverify is None


def test_service_snapshot_counters():
    with QueryService(max_concurrent=1) as svc:
        svc.submit([_pred("p0")], iter(_batches(np.arange(16))),
                   **_EXEC_KW).result(timeout=30)
        snap = svc.snapshot()
    assert snap["submitted"] == 1 and snap["completed"] == 1
    assert snap["pending"] == 0 and snap["running"] == 0
    assert snap["rejected"] == 0 and snap["failed"] == 0
    assert "arbiter" in snap and "rebalances" in snap["arbiter"]


def test_failed_query_raises_and_keeps_report():
    def boom(d):
        raise ValueError("kaboom")

    udf = UDF("b_udf", fn=boom, columns=("x",), bucket=False)
    bad = Predicate("pb", udf, compare=lambda o: o.astype(bool))
    with QueryService(max_concurrent=1) as svc:
        h = svc.submit([bad], iter(_batches(np.arange(8))), **_EXEC_KW)
        with pytest.raises(RuntimeError, match="kaboom"):
            h.result(timeout=30)
    assert h.report.state == "FAILED"
    assert svc.snapshot()["failed"] == 1


# --------------------------------------------------------------------------- #
# Admission control / priority / deadline / cancel
# --------------------------------------------------------------------------- #
def _blocker(svc, name="blk", batches=6, sleep=0.05):
    """Submit a slow query and wait until it is RUNNING."""
    ids = np.arange(batches * 8)
    h = svc.submit([_pred(name, sleep=sleep)], iter(_batches(ids)),
                   **_EXEC_KW)
    deadline = time.monotonic() + 10
    while h.state == "PENDING" and time.monotonic() < deadline:
        time.sleep(0.005)
    assert h.state == "RUNNING"
    return h


def test_admission_rejects_when_pending_full():
    with QueryService(max_concurrent=1, max_pending=1) as svc:
        blk = _blocker(svc)
        q2 = svc.submit([_pred("p2")], iter(_batches(np.arange(8))),
                        **_EXEC_KW)
        with pytest.raises(AdmissionError, match="pending queue full"):
            svc.submit([_pred("p3")], iter(_batches(np.arange(8))),
                       **_EXEC_KW)
        assert svc.snapshot()["rejected"] == 1
        assert blk.result(timeout=30).state == "DONE"
        assert q2.result(timeout=30).state == "DONE"


def test_priority_orders_pending_dispatch():
    with QueryService(max_concurrent=1, max_pending=8) as svc:
        blk = _blocker(svc)
        lo = svc.submit([_pred("lo")], iter(_batches(np.arange(8))),
                        priority=1.0, **_EXEC_KW)
        hi = svc.submit([_pred("hi")], iter(_batches(np.arange(8))),
                        priority=5.0, **_EXEC_KW)
        blk.result(timeout=30)
        lo_rep = lo.result(timeout=30)
        hi_rep = hi.result(timeout=30)
    assert hi_rep.started_at < lo_rep.started_at   # hi jumped the queue


def test_pending_query_expires_at_deadline():
    with QueryService(max_concurrent=1, max_pending=8) as svc:
        blk = _blocker(svc, batches=8)
        doomed = svc.submit([_pred("dd")], iter(_batches(np.arange(8))),
                            deadline_s=0.05, **_EXEC_KW)
        rep = doomed.result(timeout=10)            # expired, not run
        assert rep.state == "EXPIRED"
        assert rep.deadline_met is False
        assert rep.started_at is None and rep.rows == 0
        assert svc.snapshot()["expired"] == 1
        blk.result(timeout=30)


def test_deadline_met_recorded_on_finish():
    with QueryService(max_concurrent=1) as svc:
        h = svc.submit([_pred("p0")], iter(_batches(np.arange(16))),
                       deadline_s=60.0, **_EXEC_KW)
        assert h.result(timeout=30).deadline_met is True


def test_cancel_pending_and_running():
    with QueryService(max_concurrent=1, max_pending=8) as svc:
        blk = _blocker(svc, batches=10)
        pend = svc.submit([_pred("pc")], iter(_batches(np.arange(8))),
                          **_EXEC_KW)
        assert pend.cancel()
        assert pend.result(timeout=10).state == "CANCELLED"
        assert blk.cancel()                        # running: stops early
        rep = blk.result(timeout=30)
        assert rep.state == "CANCELLED"
        assert rep.batches < 10                    # did not finish the scan
        assert svc.snapshot()["cancelled"] == 2
    assert not blk.cancel()                        # already terminal


def test_closed_service_rejects_submit():
    svc = QueryService(max_concurrent=1)
    svc.close()
    with pytest.raises(AdmissionError, match="closed"):
        svc.submit([_pred("p0")], iter(_batches(np.arange(8))), **_EXEC_KW)


# --------------------------------------------------------------------------- #
# Name conflicts + the cross-query live-prior channel
# --------------------------------------------------------------------------- #
def test_same_predicate_name_serialized_not_crosswired():
    """Arbiter registrations are name-keyed: two queries sharing a
    predicate NAME must run one-after-the-other, both correctly."""
    ids_a, ids_b = np.arange(32), np.arange(100, 132)
    with QueryService(max_concurrent=2) as svc:
        h1 = svc.submit([_pred("shared", sleep=0.02)],
                        iter(_batches(ids_a)), **_EXEC_KW)
        h2 = svc.submit([_pred("shared")], iter(_batches(ids_b)),
                        **_EXEC_KW)
        r1, r2 = h1.result(timeout=60), h2.result(timeout=60)
    assert r1.state == "DONE" and r2.state == "DONE"
    assert Counter(map(int, r1.row_ids)) == _expected(ids_a, 2)
    assert Counter(map(int, r2.row_ids)) == _expected(ids_b, 2)
    # serialized: the second never overlapped the first
    first, second = sorted((r1, r2), key=lambda r: r.started_at)
    assert second.started_at >= first.finished_at


def test_live_priors_flow_between_concurrent_queries():
    """Query B admitted WHILE query A is mid-flight: A's live board is
    folded into the shared store before B warm-starts, so B's profile
    channel has A's fingerprint before A ever finishes."""
    fp = "kernel|shared-probe|cmv=1"
    with QueryService(max_concurrent=2) as svc:
        a = svc.submit([_pred("qa", sleep=0.03, fingerprint=fp)],
                       iter(_batches(np.arange(80))), **_EXEC_KW)
        deadline = time.monotonic() + 10
        while a.report.batches < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert a.report.batches >= 2           # A is mid-flight, profiled
        b = svc.submit([_pred("qb", fingerprint=fp)],
                       iter(_batches(np.arange(8))), **_EXEC_KW)
        b.result(timeout=30)
        rec = svc.store.get(fp)
        assert rec is not None                 # folded from A's LIVE board
        a.result(timeout=60)
    assert svc.store.get(fp)["cost_per_row"] > 0


def test_finished_query_profile_persists_in_store():
    p = _pred("p0")
    with QueryService(max_concurrent=1) as svc:
        svc.submit([p], iter(_batches(np.arange(32))),
                   **_EXEC_KW).result(timeout=30)
        assert svc.store.get(fingerprint_of(p)) is not None


# --------------------------------------------------------------------------- #
# The _service snapshot key contract
# --------------------------------------------------------------------------- #
def test_standalone_executor_service_key_unmanaged():
    ex = AQPExecutor([_pred("p0")], **_EXEC_KW)
    ex.collect(iter(_batches(np.arange(8))))
    assert ex.stats_snapshot()["_service"] == {"managed": False}


def test_managed_executor_service_key_identifies_query():
    ex = AQPExecutor([_pred("p0")], query="q7", **_EXEC_KW)
    ex.service_info = {"managed": True, "query": "q7",
                       "priority": 2.0, "deadline_s": 5.0}
    ex.collect(iter(_batches(np.arange(8))))
    svc = ex.stats_snapshot()["_service"]
    assert svc["managed"] is True and svc["query"] == "q7"
    assert svc["priority"] == 2.0 and svc["deadline_s"] == 5.0


# --------------------------------------------------------------------------- #
# Multi-tenant isolation under real concurrency
# --------------------------------------------------------------------------- #
def test_concurrent_tenants_exact_multisets_and_no_board_leakage():
    """Four queries in flight on one shared arbiter: every report carries
    exactly its own predicate's board entries and its exact row-id
    multiset — no cross-query statistics or row leakage."""
    specs = [(f"t{i}m{m}", m, np.arange(i * 1000, i * 1000 + 96))
             for i, m in enumerate((2, 3, 5, 7))]
    with QueryService(max_concurrent=4, max_pending=8) as svc:
        handles = [
            (name, m, ids,
             svc.submit([_pred(name, keep_mod=m)], iter(_batches(ids)),
                        **_EXEC_KW))
            for name, m, ids in specs
        ]
        reports = [(name, m, ids, h.result(timeout=60))
                   for name, m, ids, h in handles]
    for name, m, ids, rep in reports:
        assert rep.state == "DONE"
        assert rep.board_predicates == (name,), rep.board_predicates
        assert Counter(map(int, rep.row_ids)) == _expected(ids, m)
