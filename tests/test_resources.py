"""Elastic resource arbiter (core/resources.py): device-slot leasing,
pressure-ranked arbitration, scale-down retirement, cross-predicate slot
handoff (with SimClock horizon inheritance), and the thread-affine launch
attribution that keeps concurrent executors from cross-recording kernel
timings — the two closed ROADMAP residuals."""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (
    AQPExecutor, DevicePool, Predicate, PressureRanked, ResourceArbiter,
    SimClock, StaticPartition, UDF, make_batch,
)
from repro.core.stats import StatsBoard


def _fake_factory(name):
    def factory(i):
        return SimpleNamespace(wid=f"{name}#{i}", index=i,
                               device_group="g", queue=[])
    return factory


def _register(arb, name, n=3, board=None, clock=None):
    arb.register(name, num_workers=n, factory=_fake_factory(name),
                 stats=board, clock=clock)


def _pred(name, *, sleep=0.0):
    def fn(d):
        if sleep:
            time.sleep(sleep)
        return np.ones(len(d["x"]), bool)

    udf = UDF(name + "_udf", fn=fn, columns=("x",))
    return Predicate(name, udf, compare=lambda o: o.astype(bool))


def _batches(n, per=10):
    return [make_batch({"x": np.ones((per, 4))}, np.arange(i, i + per))
            for i in range(0, n, per)]


# --------------------------------------------------------------------------- #
# DevicePool                                                                  #
# --------------------------------------------------------------------------- #
def test_device_pool_capacity_and_lifo_reissue():
    pool = DevicePool({"tpu:0": 2})
    s1 = pool.try_acquire("tpu:0")
    s2 = pool.try_acquire("tpu:0")
    assert s1 is not None and s2 is not None
    assert pool.try_acquire("tpu:0") is None       # bounded group exhausted
    assert pool.in_use("tpu:0") == 2
    pool.release(s2)
    assert pool.try_acquire("tpu:0") is s2          # LIFO: warmest slot first
    # unlisted groups are unbounded by default (pre-arbiter behavior)
    assert all(pool.try_acquire("cpu") is not None for _ in range(100))


def test_device_pool_default_capacity_bounds_unlisted_groups():
    pool = DevicePool(default_capacity=1)
    assert pool.try_acquire("anything") is not None
    assert pool.try_acquire("anything") is None


# --------------------------------------------------------------------------- #
# Arbiter: lease lifecycle + cross-predicate handoff                          #
# --------------------------------------------------------------------------- #
def test_released_lease_claimable_by_another_predicate():
    """The §5.2 core: a slot retired by one predicate is claimable by
    another predicate's router (ROADMAP reallocation residual)."""
    arb = ResourceArbiter(pool=DevicePool({"g": 1}))
    _register(arb, "a")
    _register(arb, "b")

    wa = arb.lease("a")
    assert wa is not None                  # floor lease for a
    assert arb.lease("b") is None          # pool exhausted: b denied
    assert arb.counters()["denials"] == 1

    arb.release("a", wa)                   # a drains, slot returns
    wb = arb.lease("b")
    assert wb is not None                  # b claims the released slot
    c = arb.counters()
    assert c["cross_pred_handoffs"] == 1
    assert c["leases"] == 2 and c["releases"] == 1


def test_lease_at_own_ceiling_returns_none():
    arb = ResourceArbiter()
    _register(arb, "a", n=2)
    assert arb.lease("a") is not None
    assert arb.lease("a") is not None
    assert arb.lease("a") is None          # all contexts leased


def test_unregister_returns_all_slots():
    pool = DevicePool({"g": 2})
    arb = ResourceArbiter(pool=pool)
    _register(arb, "a")
    arb.lease("a")
    arb.lease("a")
    assert pool.in_use("g") == 2
    arb.unregister("a")
    assert pool.in_use("g") == 0
    # the registration (contexts included) is dropped: a long-lived shared
    # arbiter must not accumulate dead executors' worker graphs
    assert arb.contexts("a") == []
    assert arb.lease("a") is None          # unregistered: no lease, no raise


def test_reregister_after_unregister_reuses_shared_arbiter():
    """Sequential executors may reuse a shared arbiter: a name
    re-registers only after unregister; a currently-registered name is
    rejected outright (silent replacement would cross-wire pipelines)."""
    arb = ResourceArbiter(pool=DevicePool({"g": 1}))
    _register(arb, "a")
    with pytest.raises(ValueError, match="already registered"):
        _register(arb, "a")               # even with zero live leases
    w = arb.lease("a")
    arb.release("a", w)
    arb.unregister("a")
    _register(arb, "a", n=2)               # fresh registration succeeds
    assert len(arb.contexts("a")) == 2
    assert arb.lease("a") is not None


def test_concurrent_executors_cannot_share_arbiter_with_same_names():
    """The cross-wiring hazard is rejected at CONSTRUCTION of the second
    executor, not discovered as a hang at run time."""
    arb = ResourceArbiter()
    AQPExecutor([_pred("p")], arbiter=arb)
    with pytest.raises(ValueError, match="already registered"):
        AQPExecutor([_pred("p")], arbiter=arb)


# --------------------------------------------------------------------------- #
# Arbitration policies                                                        #
# --------------------------------------------------------------------------- #
def test_pressure_ranked_grants_highest_pressure_claimant():
    board = StatsBoard(["a", "b", "c"])
    board["b"].cost_per_row.update(1.0)    # b is expensive; a is ~free
    arb = ResourceArbiter(pool=DevicePool({"g": 3}),
                          policy=PressureRanked())
    for name in ("a", "b", "c"):
        _register(arb, name, board=board)

    wc = arb.lease("c")
    wa = arb.lease("a")
    wb = arb.lease("b")                    # pool now full
    wb.queue.extend([1, 2, 3])             # b: deep queue -> high pressure
    assert arb.lease("a") is None          # denied (pool full), a now wants
    assert arb.lease("b") is None          # denied (pool full), b now wants
    assert arb.pressure_of("b") > arb.pressure_of("a")

    arb.release("c", wc)                   # one slot frees up
    assert arb.lease("a") is None          # outranked by b's standing claim
    assert arb.lease("b") is not None      # highest pressure wins the slot
    assert arb.counters()["cross_pred_handoffs"] == 1
    assert wa is not None


def test_pressure_ranking_is_device_group_scoped():
    """A rival's standing claim on an EXHAUSTED group must not block a
    requester's free capacity on a disjoint group."""
    board = StatsBoard(["gpu_pred", "cpu_pred"])
    board["gpu_pred"].cost_per_row.update(1.0)
    arb = ResourceArbiter(pool=DevicePool({"gpu": 1, "cpu": 4}),
                          policy=PressureRanked())

    def gpu_factory(i):
        return SimpleNamespace(wid=f"gpu_pred#{i}", index=i,
                               device_group="gpu", queue=[])

    arb.register("gpu_pred", num_workers=3, factory=gpu_factory, stats=board)
    _register(arb, "cpu_pred", board=board)  # group "g"... uses "g"
    # move cpu_pred's contexts onto the cpu group
    for w in arb.contexts("cpu_pred"):
        w.device_group = "cpu"

    wg = arb.lease("gpu_pred")               # gpu group now full
    wg.queue.extend([1, 2, 3])               # high pressure
    assert arb.lease("gpu_pred") is None     # denied: standing gpu claim
    wc = arb.lease("cpu_pred")               # floor on cpu
    # non-floor cpu request: gpu_pred's claim is for a group cpu_pred's
    # slot could never satisfy — must be granted, not blocked
    assert wc is not None
    assert arb.lease("cpu_pred") is not None


def test_static_partition_quota_and_no_scale_down():
    arb = ResourceArbiter(policy=StaticPartition(quota=1))
    _register(arb, "a")
    assert not arb.scale_down_enabled
    assert arb.lease("a") is not None      # floor
    assert arb.lease("a") is None          # quota of 1: pool never rebalances


# --------------------------------------------------------------------------- #
# SimClock lease handoff                                                      #
# --------------------------------------------------------------------------- #
def test_simclock_lease_handoff_transfers_horizon():
    c = SimClock()
    c.occupy_shared("w1", "dev", 5.0, 0.0, ready=0.0)
    c.lease_handoff("w1", "w2")
    assert c.resource_busy_until("w2") == 5.0
    assert c.resource_busy_until("w1") == 0.0   # MOVED, not copied
    # never moves a horizon backwards (w1 already drained to 0 here)
    c.occupy_shared("w3", "dev", 9.0, 0.0, ready=0.0)
    c.lease_handoff("w1", "w3")
    assert c.resource_busy_until("w3") == 9.0
    assert c.makespan == 9.0                    # survives detached entries


def test_handoff_does_not_double_count_on_re_lease():
    """A handed-off horizon must not linger on the retired worker: when
    the same context is later re-leased, it starts from the SLOT's
    inherited horizon — the same virtual work is never scheduled twice."""
    clk = SimClock()
    arb = ResourceArbiter(pool=DevicePool({"g": 1}))
    _register(arb, "a", clock=clk)
    _register(arb, "b", clock=clk)
    wa = arb.lease("a")
    clk.occupy_shared(wa.wid, "g", 10.0, 0.0, ready=0.0)
    arb.release("a", wa)
    wb = arb.lease("b")
    assert clk.resource_busy_until(wb.wid) == 10.0
    assert clk.resource_busy_until(wa.wid) == 0.0
    arb.release("b", wb)
    wa2 = arb.lease("a")                         # same context, re-leased
    assert wa2.wid == wa.wid
    assert clk.resource_busy_until(wa2.wid) == 10.0
    assert clk.makespan == 10.0                  # counted exactly once


def test_cross_clock_handoff_via_shared_pool():
    """Two executors sharing only the DevicePool (separate arbiters and
    SimClocks): the horizon travels on the Slot itself."""
    pool = DevicePool({"g": 1})
    clk1, clk2 = SimClock(), SimClock()
    arb1 = ResourceArbiter(pool=pool)
    arb2 = ResourceArbiter(pool=pool)
    arb1.register("a", num_workers=1, factory=_fake_factory("a"), clock=clk1)
    arb2.register("b", num_workers=1, factory=_fake_factory("b"), clock=clk2)
    wa = arb1.lease("a")
    clk1.occupy_shared(wa.wid, "g", 7.0, 0.0, ready=0.0)
    arb1.release("a", wa)
    wb = arb2.lease("b")
    assert clk2.resource_busy_until(wb.wid) == 7.0


def test_constructed_but_never_run_executor_holds_no_slots():
    """The floor lease is lazy (first submit), so an abandoned executor
    never strands shared-pool capacity."""
    pool = DevicePool({"cpu": 1})
    AQPExecutor([_pred("a")], pool=pool)    # constructed, never run
    assert pool.in_use("cpu") == 0
    ex2 = AQPExecutor([_pred("b")], pool=pool)
    got = sum(b.rows for b in ex2.run(iter(_batches(20))))
    assert got == 20                        # the slot was still available


def test_undersized_pool_rejected_at_construction():
    """A bounded pool that cannot hold one floor slot per predicate is a
    guaranteed starvation — rejected before any query runs."""
    with pytest.raises(ValueError, match="starve"):
        AQPExecutor([_pred("a"), _pred("b")], pool=DevicePool({"cpu": 1}))
    # per-group: two predicates pinned to the same 1-slot group
    with pytest.raises(ValueError, match="starve"):
        AQPExecutor([_pred("a"), _pred("b")],
                    pool=DevicePool({"cpu": 1, "tpu:0": 4}),
                    devices={"a": ("cpu",), "b": ("cpu",)})
    # an unbounded group absorbs any floor demand
    AQPExecutor([_pred("a"), _pred("b")], pool=DevicePool())


def test_floor_starvation_raises_instead_of_hanging(monkeypatch):
    """A floor lease denied at RUN time (capacity hoarded elsewhere, e.g.
    by another executor on the shared pool) must surface an error after
    the deadline, not spin forever."""
    from repro.core import laminar

    monkeypatch.setattr(laminar, "FLOOR_STARVATION_DEADLINE_S", 0.3)
    pool = DevicePool({"cpu": 1})
    hoarded = pool.try_acquire("cpu")        # a rival holds the only slot
    assert hoarded is not None
    ex = AQPExecutor([_pred("a")], pool=pool, warmup=False)
    with pytest.raises(RuntimeError, match="starved"):
        ex.collect(iter(_batches(20)))


def test_failed_construction_unregisters_partial_registration():
    """A constructor that fails mid-way (name collision on a shared
    arbiter) must not poison the names it already registered."""
    arb = ResourceArbiter()
    AQPExecutor([_pred("p")], arbiter=arb)   # 'p' now registered
    with pytest.raises(ValueError, match="already registered"):
        AQPExecutor([_pred("x"), _pred("p")], arbiter=arb)
    # 'x' was rolled back: a corrected retry works
    ex = AQPExecutor([_pred("x")], arbiter=arb)
    got = sum(b.rows for b in ex.run(iter(_batches(20))))
    assert got == 20


def test_executor_rejects_arbiter_plus_pool_or_policy():
    with pytest.raises(ValueError, match="pre-built arbiter"):
        AQPExecutor([_pred("p")], arbiter=ResourceArbiter(),
                    pool=DevicePool())
    with pytest.raises(ValueError, match="pre-built arbiter"):
        AQPExecutor([_pred("p")], arbiter=ResourceArbiter(),
                    arbiter_policy=StaticPartition())


def test_arbiter_handoff_inherits_simclock_horizon():
    clk = SimClock()
    arb = ResourceArbiter(pool=DevicePool({"g": 1}))
    _register(arb, "a", clock=clk)
    _register(arb, "b", clock=clk)
    wa = arb.lease("a")
    clk.occupy_shared(wa.wid, "g", 4.0, 0.0, ready=0.0)
    arb.release("a", wa)
    wb = arb.lease("b")
    # the physical slot's virtual horizon moved with the lease
    assert clk.resource_busy_until(wb.wid) == 4.0


# --------------------------------------------------------------------------- #
# Scale-down integration: idle workers retire and free their slot             #
# --------------------------------------------------------------------------- #
def test_idle_worker_retires_and_slot_is_reclaimed():
    p = _pred("p", sleep=0.01)

    def source():
        for b in _batches(300):
            yield b
        time.sleep(0.4)        # drain gap: workers idle past the threshold
        for b in _batches(20):
            yield b

    ex = AQPExecutor([p], max_workers=4, warmup=False,
                     drain_threshold=0.05)
    got = sum(b.rows for b in ex.run(source()))
    assert got == 320
    lam = ex.laminars["p"]
    assert lam.retirements >= 1, "idle worker never retired"
    snap = ex.stats_snapshot()
    assert snap["_arbiter"]["releases"] >= 1
    assert snap["_arbiter"]["leases"] > snap["_arbiter"]["releases"] - 1
    # shutdown released every slot: no fabricated post-run leases
    assert ex.leased_worker_counts() == {"p": 0}


def test_default_drain_threshold_preserves_short_run_behavior():
    """With the generous default threshold, short runs never retire —
    identical to the pre-arbiter private pools."""
    p = _pred("p", sleep=0.005)
    ex = AQPExecutor([p], max_workers=4, warmup=False)
    ex.collect(iter(_batches(100)))
    assert ex.laminars["p"].retirements == 0


# --------------------------------------------------------------------------- #
# Per-executor launch attribution (concurrent executors, ROADMAP residual)    #
# --------------------------------------------------------------------------- #
def test_concurrent_executors_do_not_cross_record_kernel_timings():
    """Two executors with DIFFERENT kernel-backed predicates run at the
    same time in one process; each StatsBoard must hold only its own
    kernel's launch entries (no cross-recorded ``kernel:*``/kernel-name
    entries from the other executor)."""
    from repro import udfs

    SIZE, SEQ, N = 8, 16, 12
    rng = np.random.default_rng(0)
    crops = rng.uniform(0, 255, (N, SIZE, SIZE, 3)).astype(np.float32)
    tokens = rng.integers(1, 256, (N, 12)).astype(np.int32)

    ex_hsv = AQPExecutor([udfs.color_predicate("black", size=SIZE)],
                         max_workers=2, warmup=False)
    ex_moe = AQPExecutor([udfs.topic_router_predicate(0, n_experts=4, seq=SEQ)],
                         max_workers=2, warmup=False)

    def batches(col, arr):
        return [make_batch({col: arr[i:i + 4]}, np.arange(i, i + 4))
                for i in range(0, N, 4)]

    errors = []

    def consume(ex, src):
        try:
            list(ex.run(iter(src)))
        except BaseException as e:  # surfaced via the errors list
            errors.append(e)

    t1 = threading.Thread(target=consume,
                          args=(ex_hsv, batches("crop", crops)))
    t2 = threading.Thread(target=consume,
                          args=(ex_moe, batches("tokens", tokens)))
    t1.start(); t2.start()
    t1.join(timeout=120); t2.join(timeout=120)
    assert not errors, errors
    assert not t1.is_alive() and not t2.is_alive()

    snap_hsv = ex_hsv.stats_snapshot()
    snap_moe = ex_moe.stats_snapshot()
    # each board saw its OWN kernel...
    assert any("hsv_color" in k for k in snap_hsv)
    assert any("moe_router" in k for k in snap_moe)
    # ...and nothing from the other executor's launches
    assert not any("moe_router" in k for k in snap_hsv), snap_hsv.keys()
    assert not any("hsv_color" in k for k in snap_moe), snap_moe.keys()


def test_token_hooks_are_thread_affine(rng):
    import jax.numpy as jnp

    from repro.kernels import launch, ops

    events_tok, events_glob = [], []
    tok = object()
    h_tok = launch.add_launch_hook(events_tok.append, token=tok)
    h_glob = launch.add_launch_hook(events_glob.append)
    try:
        logits = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
        ops.moe_topk_router(logits, 2, impl="pallas")   # untagged thread
        assert events_tok == []
        assert len(events_glob) == 1
        with launch.launch_context(tok):                # tagged
            ops.moe_topk_router(logits, 2, impl="pallas")
        assert len(events_tok) == 1
        assert len(events_glob) == 2
        assert launch.current_launch_context() is None  # context restored
    finally:
        launch.remove_launch_hook(h_tok)
        launch.remove_launch_hook(h_glob)
    assert tok not in launch._TOKEN_HOOKS               # registry cleaned up


# --------------------------------------------------------------------------- #
# Multi-tenant arbitration: urgency, query identity, rebalance (QueryService) #
# --------------------------------------------------------------------------- #
def test_urgency_weight_priority_and_deadline():
    from repro.core import urgency_weight

    assert urgency_weight() == 1.0
    assert urgency_weight(2.0) == 2.0                  # priority is linear
    assert urgency_weight(-3.0) == 0.0                 # clamped at zero
    soon = urgency_weight(1.0, deadline=100.5, now=100.0)
    later = urgency_weight(1.0, deadline=150.0, now=100.0)
    assert soon > later > 1.0                          # proximity urgency
    # an already-missed deadline saturates instead of diverging
    assert urgency_weight(1.0, deadline=90.0, now=100.0) == pytest.approx(11.0)


def test_pressure_ranked_urgency_breaks_pressure_tie():
    """Equal measured pressure: the higher-urgency (deadline-pressed /
    higher-priority) claimant wins the contended slot; with no urgency
    map the comparison is bit-identical to the pre-service behavior."""
    pol = PressureRanked()
    pressures = {"a": 1.0, "b": 1.0}
    wants = {"a": True, "b": True}
    assert pol.grant("a", pressures=pressures, wants=wants, held={})
    assert pol.grant("b", pressures=pressures, wants=wants, held={})
    urgency = {"a": 1.0, "b": 3.0}
    assert not pol.grant("a", pressures=pressures, wants=wants, held={},
                         urgency=urgency)
    assert pol.grant("b", pressures=pressures, wants=wants, held={},
                     urgency=urgency)


def test_slots_carry_query_identity_and_count_handoffs():
    arb = ResourceArbiter(pool=DevicePool({"g": 1}))
    arb.register("a", num_workers=2, factory=_fake_factory("a"), query="q1")
    arb.register("b", num_workers=2, factory=_fake_factory("b"), query="q2")
    wa = arb.lease("a")
    arb.release("a", wa)
    wb = arb.lease("b")                    # q1 -> q2: cross-query handoff
    assert arb.counters()["cross_query_handoffs"] == 1
    arb.release("b", wb)
    assert arb.lease("b") is not None      # q2 -> q2: same query, no count
    assert arb.counters()["cross_query_handoffs"] == 1


def test_admit_finish_rebalance_clears_stale_wants():
    """note_query_admitted/-finished rebalance WITHOUT preemption: stale
    zero-pressure standing claims are dropped, held leases untouched."""
    board = StatsBoard(["a", "b"])
    board["a"].cost_per_row.update(1.0)
    arb = ResourceArbiter(pool=DevicePool({"g": 1}),
                          policy=PressureRanked())
    _register(arb, "a", board=board)
    _register(arb, "b", board=board)
    wa = arb.lease("a")                    # floor lease: pool now full
    assert arb.lease("b") is None          # b: standing want, zero pressure
    arb.note_query_admitted("q2", 2.0)
    assert arb.counters()["rebalances"] == 1
    assert not arb._wants["b"]             # stale want cleared
    assert len(arb.leased("a")) == 1       # a's lease survived untouched
    arb.note_query_finished("q2")
    assert arb.counters()["rebalances"] == 2
    assert wa is not None


# --------------------------------------------------------------------------- #
# Virtual-idle drain under SimClock (ROADMAP residual)                        #
# --------------------------------------------------------------------------- #
def _sim_arrival_source(pred_col_batches, late_sim_ready, gap_s):
    from dataclasses import replace

    def source():
        for b in pred_col_batches[:-1]:
            yield b                              # burst at virtual t=0
        # the late arrival advances the router's virtual frontier...
        yield replace(pred_col_batches[-1], sim_ready=late_sim_ready)
        # ...then a WALL gap gives the idle polls time to read it
        time.sleep(gap_s)

    return source


def test_virtual_idle_drain_retires_under_simclock():
    """``virtual_drain=True``: scale-down verdicts read VIRTUAL idleness
    (sim frontier vs worker busy horizon), so a simulated arrival gap
    retires scaled-up workers even though wall-clock idle is milliseconds."""
    from repro.udfs.synthetic import planted_predicate

    p = planted_predicate("p", range(10000), cost_per_row=0.1)
    batches = [make_batch({"rid": np.arange(i, i + 10)},
                          np.arange(i, i + 10))
               for i in range(0, 300, 10)]
    ex = AQPExecutor([p], clock=SimClock(), max_workers=4, warmup=False,
                     virtual_drain=True, drain_threshold=5.0)
    out = list(ex.run(_sim_arrival_source(batches, 1e6, gap_s=0.4)()))
    assert sum(b.rows for b in out) == 300
    assert ex.laminars["p"].retirements >= 1, \
        "virtual arrival gap never retired a scaled-up worker"


def test_simclock_without_virtual_drain_never_retires():
    from repro.udfs.synthetic import planted_predicate

    p = planted_predicate("p", range(10000), cost_per_row=0.1)
    batches = [make_batch({"rid": np.arange(i, i + 10)},
                          np.arange(i, i + 10))
               for i in range(0, 300, 10)]
    ex = AQPExecutor([p], clock=SimClock(), max_workers=4, warmup=False,
                     drain_threshold=5.0)
    out = list(ex.run(_sim_arrival_source(batches, 1e6, gap_s=0.3)()))
    assert sum(b.rows for b in out) == 300
    assert ex.laminars["p"].retirements == 0   # pinned SimClock behavior


# --------------------------------------------------------------------------- #
# Multi-tenant service stress: attribution + per-query correctness            #
# --------------------------------------------------------------------------- #
def test_service_tenants_no_cross_query_kernel_leakage():
    """The QueryService version of the cross-record attribution test:
    kernel-backed tenants run CONCURRENTLY under one shared arbiter, and
    each QueryReport's board holds only its own kernel's entries and its
    exact standalone row-id multiset."""
    from collections import Counter

    from repro import udfs
    from repro.launch.serve import QueryService

    SIZE, SEQ, N = 8, 16, 12
    rng = np.random.default_rng(0)
    crops = rng.uniform(0, 255, (N, SIZE, SIZE, 3)).astype(np.float32)
    tokens = rng.integers(1, 256, (N, 12)).astype(np.int32)

    def batches(col, arr):
        return [make_batch({col: arr[i:i + 4]}, np.arange(i, i + 4))
                for i in range(0, N, 4)]

    def preds():
        return {
            "crop": udfs.color_predicate("black", size=SIZE),
            "tokens": udfs.topic_router_predicate(0, n_experts=4, seq=SEQ),
        }

    # expected multisets from standalone serial runs of the SAME data
    expected = {}
    for col, arr in (("crop", crops), ("tokens", tokens)):
        ex = AQPExecutor([preds()[col]], max_workers=2, warmup=False)
        expected[col] = Counter(
            int(i) for b in ex.collect(iter(batches(col, arr)))
            for i in b.row_ids
        )

    with QueryService(max_concurrent=2) as svc:
        h_hsv = svc.submit([preds()["crop"]], iter(batches("crop", crops)),
                           max_workers=2, warmup=False)
        h_moe = svc.submit([preds()["tokens"]],
                           iter(batches("tokens", tokens)),
                           max_workers=2, warmup=False)
        rep_hsv = h_hsv.result(timeout=120)
        rep_moe = h_moe.result(timeout=120)

    assert rep_hsv.state == "DONE" and rep_moe.state == "DONE"
    assert Counter(map(int, rep_hsv.row_ids)) == expected["crop"]
    assert Counter(map(int, rep_moe.row_ids)) == expected["tokens"]
    # each board saw its OWN kernel and nothing from the other tenant
    assert any("hsv_color" in k for k in rep_hsv.board_predicates)
    assert any("moe_router" in k for k in rep_moe.board_predicates)
    assert not any("moe_router" in k for k in rep_hsv.board_predicates)
    assert not any("hsv_color" in k for k in rep_moe.board_predicates)
