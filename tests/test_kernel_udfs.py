"""End-to-end integration: kernel-backed predicates through AQPExecutor.

The ISSUE-2 acceptance path: ``AQPExecutor.run()`` over predicates from
``repro.udfs`` must (a) produce exactly the oracle conjunctive result,
(b) populate the StatsBoard with per-launch kernel cost observations (the
launch hook actually fired), and (c) deregister the hook when the run is
over, so back-to-back executors never double-count each other's launches.

Everything runs in Pallas interpret mode (auto-selected off-TPU) on tiny
shapes; kernel-vs-reference numerics live in test_kernels.py, routing
correctness across policies in test_property.py — this file is about the
seam between the two subsystems.
"""
import numpy as np
import pytest

from repro import udfs
from repro.core import AQPExecutor, CostDriven, make_batch
from repro.core.udf import UDF, bucket_rows
from repro.kernels import launch

SIZE = 8     # crop height/width for the hsv predicate
SEQ = 16     # token sequence length for the text predicates


def _dataset(n=24, seed=0):
    """Crops with planted dark/bright thirds + random token sequences."""
    rng = np.random.default_rng(seed)
    crops = rng.uniform(0, 255, (n, SIZE, SIZE, 3)).astype(np.float32)
    crops[: n // 3] = rng.uniform(0, 40, (n // 3, SIZE, SIZE, 3))  # black-ish
    tokens = rng.integers(1, 256, (n, 12)).astype(np.int32)
    return {"crop": crops, "tokens": tokens}


def _batches(data, per=6):
    n = len(data["crop"])
    return [
        make_batch({k: v[i:i + per] for k, v in data.items()},
                   np.arange(i, min(i + per, n)))
        for i in range(0, n, per)
    ]


def _oracle_ids(preds, data):
    n = len(next(iter(data.values())))
    mask = np.ones(n, bool)
    for p in preds:
        mask &= p.mask_from_outputs(p.udf(data))
    return set(np.nonzero(mask)[0].tolist())


def _make_preds():
    return [
        udfs.color_predicate("black", size=SIZE),
        udfs.topic_router_predicate(0, n_experts=4, seq=SEQ),
        udfs.ssd_scorer_predicate(0.0, seq=SEQ),
    ]


# --------------------------------------------------------------------------- #
# (a) + (b): oracle equality and kernel costs on the board                    #
# --------------------------------------------------------------------------- #
def test_executor_populates_stats_board_with_kernel_costs():
    data = _dataset()
    preds = _make_preds()
    expect = _oracle_ids(preds, data)
    assert 0 < len(expect) < len(data["crop"])  # non-trivial filter

    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=2)
    got = {int(i) for b in ex.run(iter(_batches(data))) for i in b.row_ids}
    assert got == expect

    snap = ex.stats_snapshot()
    for kernel in ("hsv_color", "moe_router", "ssd"):
        assert kernel in snap, f"launch hook never recorded {kernel}"
        assert snap[kernel]["batches"] > 0
        assert snap[kernel]["cost_per_row"] > 0
    # predicate-level stats measured too (the policies rank on these)
    for p in preds:
        assert snap[p.name]["batches"] > 0


# --------------------------------------------------------------------------- #
# (c): hook lifecycle                                                         #
# --------------------------------------------------------------------------- #
def _total_hooks():
    """Live hooks across BOTH registries — the executor's run()-lifetime
    hook is token-scoped (_TOKEN_HOOKS), not global (_HOOKS)."""
    return len(launch._HOOKS) + sum(map(len, launch._TOKEN_HOOKS.values()))


def test_hook_deregistered_after_run_and_no_double_count():
    data = _dataset()
    preds = _make_preds()
    hooks_before = _total_hooks()

    ex1 = AQPExecutor(preds, policy=CostDriven(), max_workers=2)
    list(ex1.run(iter(_batches(data))))
    assert _total_hooks() == hooks_before, "run() leaked its launch hook"
    assert ex1._kernel_hook is None

    snap1 = ex1.stats_snapshot()
    launches1 = {k: snap1[k]["batches"] for k in ("hsv_color", "moe_router")}

    # a launch outside any run must not reach the (shut-down) executor board
    udfs.color_predicate("black", size=SIZE).udf(
        {"crop": data["crop"][:4]}
    )
    assert ex1.stats_snapshot()["hsv_color"]["batches"] == launches1["hsv_color"]

    # a second executor over the same predicates counts only its own launches
    ex2 = AQPExecutor(preds, policy=CostDriven(), max_workers=2)
    list(ex2.run(iter(_batches(data))))
    snap2 = ex2.stats_snapshot()
    for k, v in launches1.items():
        assert snap2[k]["batches"] > 0
        assert ex1.stats_snapshot()[k]["batches"] == v, "double-counted"
    assert _total_hooks() == hooks_before


def test_hook_deregistered_when_worker_raises():
    def boom(d):
        raise ValueError("planted failure")

    bad = udfs.planted_predicate("ok", range(5), cost_per_row=1e-4)
    bad.udf.fn = boom
    hooks_before = _total_hooks()
    ex = AQPExecutor([bad], max_workers=1)
    batches = [make_batch({"rid": np.arange(5)}, np.arange(5))]
    with pytest.raises(RuntimeError, match="planted failure"):
        list(ex.run(iter(batches)))
    assert _total_hooks() == hooks_before
    assert ex._kernel_hook is None


# --------------------------------------------------------------------------- #
# zero-row regression (ISSUE-2 satellite): probe with a synthesized row       #
# --------------------------------------------------------------------------- #
def test_zero_row_udf_never_calls_fn_with_empty_arrays():
    seen = []

    def fn(d):
        seen.append(len(d["x"]))
        assert len(d["x"]) > 0, "zero-row probe must synthesize a row"
        return (d["x"].sum(-1) > 0).astype(np.int32)

    udf = UDF("u", fn, columns=("x",))
    out = udf({"x": np.zeros((0, 3), np.float32)})
    assert out.shape == (0,)
    assert out.dtype == np.int32   # dtype comes from the probe output
    assert seen == [1]
    # the learned output spec is cached: later empty batches are free
    # (no kernel launch, so no bogus 1-row sample on any stats board)
    again = udf({"x": np.zeros((0, 3), np.float32)})
    assert again.shape == (0,) and again.dtype == np.int32
    assert seen == [1]


def test_zero_row_after_real_batch_never_probes():
    calls = []

    def fn(d):
        calls.append(len(d["x"]))
        return d["x"].sum(-1)

    udf = UDF("u", fn, columns=("x",))
    udf({"x": np.ones((4, 3), np.float32)})   # learns the output spec
    out = udf({"x": np.zeros((0, 3), np.float32)})
    assert out.shape == (0,)
    assert calls == [4]                        # zero-row call was metadata-only


@pytest.mark.parametrize("kernel", sorted(udfs.KERNEL_PREDICATES))
def test_zero_row_path_works_for_every_kernel_predicate(kernel):
    kw = {"size": SIZE} if kernel == "hsv_color" else {"seq": SEQ}
    p = udfs.build_predicate(kernel, **kw)
    data = _dataset(n=6)
    empty = {k: v[:0] for k, v in data.items()}
    out = p.udf(empty)
    assert out.shape[0] == 0
    assert p.mask_from_outputs(out).shape == (0,)


# --------------------------------------------------------------------------- #
# bucketing invariant, deterministically (hypothesis twin in test_property)   #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("kernel", sorted(udfs.KERNEL_PREDICATES))
def test_bucket_padding_matches_unbucketed_outputs(kernel):
    kw = {"size": SIZE} if kernel == "hsv_color" else {"seq": SEQ}
    p = udfs.build_predicate(kernel, **kw)
    data = _dataset(n=5, seed=3)   # 5 -> bucketed to 8
    assert bucket_rows(5) == 8
    bucketed = p.udf(data)         # pads to 8 rows, slices back
    p.udf.bucket = False
    unbucketed = p.udf(data)
    np.testing.assert_allclose(bucketed, unbucketed, rtol=1e-5, atol=1e-6)


def test_warm_fn_precompiles_without_board_traffic():
    """GACU activation: warm_fn launches once; ensure_ready is idempotent;
    the warm probe also teaches the UDF its output spec, so zero-row
    batches afterwards never launch."""
    events = []
    p = udfs.topic_router_predicate(0, n_experts=4, seq=SEQ)
    with launch.launch_hooks(events.append):
        p.udf.ensure_ready()
        assert [e.name for e in events] == ["moe_router"]
        p.udf.ensure_ready()
        assert len(events) == 1   # second call is a no-op
        out = p.udf({"tokens": np.zeros((0, 12), np.int32)})
        assert out.shape == (0,)
        assert len(events) == 1   # zero-row call reused the warm spec


def test_kernel_name_colliding_with_predicate_name_is_namespaced():
    """A predicate deliberately named after its kernel must not have launch
    events merged into its routing entry (they would drag the lottery
    selectivity toward 1.0 and end warmup before any batch was routed)."""
    data = _dataset()
    pred = udfs.color_predicate("black", size=SIZE, name="hsv_color")
    other = udfs.topic_router_predicate(0, n_experts=4, seq=SEQ)
    expect = _oracle_ids([pred, other], data)

    ex = AQPExecutor([pred, other], policy=CostDriven(), max_workers=2)
    got = {int(i) for b in ex.run(iter(_batches(data))) for i in b.row_ids}
    assert got == expect

    snap = ex.stats_snapshot()
    assert "kernel:hsv_color" in snap          # launches, diverted
    assert snap["kernel:hsv_color"]["batches"] > 0
    # predicate entry holds ONLY routing evaluations: its lottery saw some
    # rows dropped (launch events never record wins, so selectivity would
    # be pinned at 1.0 had they been merged)
    assert snap["hsv_color"]["selectivity"] < 1.0
