"""Checkpointing: atomic roundtrip, keep-k GC, corruption-safety,
crash-resume via failure injection, elastic reshard restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config
from repro.distributed.fault_tolerance import FailureInjector, plan_rescale
from repro.launch.train import train_loop


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.int32), "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    tree = _tree()
    ck.save(7, tree)
    assert latest_step(str(tmp_path)) == 7
    got = ck.restore(7)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_keep_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    try:
        for s in (1, 2, 3, 4):
            ck.save(s, _tree())
        ck.wait()
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
        )
        assert steps == [3, 4]
    finally:
        ck.close()  # join the writer thread (leaked-thread guard)


def test_tmp_dirs_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, _tree())
    os.makedirs(os.path.join(tmp_path, "step_9.tmp"))  # simulated crash mid-save
    assert latest_step(str(tmp_path)) == 3


def test_restore_with_target_dtype_cast(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"w": jnp.ones((4,), jnp.float32)})
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    got = ck.restore(1, target)
    assert got["w"].dtype == jnp.bfloat16


def test_crash_resume_training(tmp_path):
    """Injected failure mid-run; a fresh train_loop resumes from the
    checkpoint and finishes with the SAME data order (source state saved)."""
    cfg = get_config("smollm-135m").reduce_for_smoke()
    inj = FailureInjector(fail_at=[7])
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop(cfg, steps=12, batch=2, seq=16,
                   ckpt_dir=str(tmp_path), ckpt_every=3, injector=inj)
    resumed_from = latest_step(str(tmp_path))
    assert resumed_from == 6
    out = train_loop(cfg, steps=12, batch=2, seq=16,
                     ckpt_dir=str(tmp_path), ckpt_every=3)
    assert np.isfinite(out["final_loss"])
    # uninterrupted reference run must agree on the final loss
    ref = train_loop(cfg, steps=12, batch=2, seq=16, ckpt_dir=None)
    np.testing.assert_allclose(out["final_loss"], ref["final_loss"],
                               rtol=1e-4, atol=1e-5)


def test_plan_rescale():
    assert plan_rescale(512, 16, model_parallel=16) == (31, 16)
    assert plan_rescale(256, 0, model_parallel=16) == (16, 16)
    with pytest.raises(ValueError):
        plan_rescale(16, 15, model_parallel=16)


def test_elastic_restore_changes_sharding(tmp_path):
    """Restore places arrays with the TARGET sharding (re-mesh on load)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, {"w": jnp.ones((8, 4))})
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P

    target = {
        "w": jax.ShapeDtypeStruct(
            (8, 4), jnp.float32, sharding=NamedSharding(mesh, P("data", None))
        )
    }
    got = ck.restore(1, target)
    assert got["w"].sharding.spec == P("data", None)
