"""Central-queue watermark + deadlock-prevention tests (§3.3)."""
import threading
import time

import pytest

from repro.core.queues import BoundedQueue, CentralQueue, ClosedError


def test_lambda_watermark_blocks_pull():
    q = CentralQueue(capacity=10, lam=0.3)  # pull limit = 3
    assert q.put_pull(1, timeout=0.05)
    assert q.put_pull(2, timeout=0.05)
    assert q.put_pull(3, timeout=0.05)
    assert not q.put_pull(4, timeout=0.05)  # watermark reached


def test_worker_reinsert_always_allowed():
    q = CentralQueue(capacity=10, lam=0.3)
    for i in range(3):
        q.put_pull(i, timeout=0.05)
    # workers may exceed the watermark freely (deadlock prevention)
    for i in range(7):
        q.put_worker(100 + i)
    assert len(q) == 10


def test_no_deadlock_under_full_cycle():
    """Producer at watermark + workers reinserting + consumer draining:
    the cycle must make progress (the paper's deadlock scenario)."""
    q = CentralQueue(capacity=6, lam=0.3)
    done = threading.Event()
    consumed = []

    def producer():
        for i in range(50):
            while not q.put_pull(i, timeout=0.02):
                pass
        done.set()

    def consumer():
        while not (done.is_set() and len(q) == 0):
            try:
                item = q.get(timeout=0.02)
            except TimeoutError:
                continue
            if isinstance(item, int) and item < 1000:
                q.put_worker(item + 1000)  # simulate worker reinsert
            else:
                consumed.append(item)

    t1 = threading.Thread(target=producer)
    t2 = threading.Thread(target=consumer)
    t1.start(); t2.start()
    t1.join(timeout=10)
    t2.join(timeout=10)
    assert done.is_set() and len(consumed) == 50


def test_pull_blocked_until_drained():
    q = CentralQueue(capacity=10, lam=0.3)
    for i in range(3):
        q.put_pull(i)
    ok = []

    def delayed_get():
        time.sleep(0.05)
        q.get()

    t = threading.Thread(target=delayed_get)
    t.start()
    ok.append(q.put_pull(99, timeout=1.0))  # unblocks after the get
    t.join()
    assert ok == [True]


def test_close_raises():
    q = CentralQueue()
    q.close()
    with pytest.raises(ClosedError):
        q.put_pull(1)
    with pytest.raises(ClosedError):
        q.get()


def test_close_drains_remaining():
    q = BoundedQueue(4)
    q.put(1); q.put(2)
    q.close()
    assert q.get() == 1 and q.get() == 2
    with pytest.raises(ClosedError):
        q.get()


def test_bounded_queue_capacity():
    q = BoundedQueue(2)
    assert q.try_put(1) and q.try_put(2)
    assert not q.try_put(3)
    q.get()
    assert q.try_put(3)
