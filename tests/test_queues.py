"""Central-queue watermark + deadlock-prevention tests (§3.3)."""
import threading
import time

import pytest

from repro.core.queues import BoundedQueue, CentralQueue, ClosedError


def test_lambda_watermark_blocks_pull():
    q = CentralQueue(capacity=10, lam=0.3)  # pull limit = 3
    assert q.put_pull(1, timeout=0.05)
    assert q.put_pull(2, timeout=0.05)
    assert q.put_pull(3, timeout=0.05)
    assert not q.put_pull(4, timeout=0.05)  # watermark reached


def test_worker_reinsert_always_allowed():
    q = CentralQueue(capacity=10, lam=0.3)
    for i in range(3):
        q.put_pull(i, timeout=0.05)
    # workers may exceed the watermark freely (deadlock prevention)
    for i in range(7):
        q.put_worker(100 + i)
    assert len(q) == 10


def test_no_deadlock_under_full_cycle():
    """Producer at watermark + workers reinserting + consumer draining:
    the cycle must make progress (the paper's deadlock scenario)."""
    q = CentralQueue(capacity=6, lam=0.3)
    done = threading.Event()
    consumed = []

    def producer():
        for i in range(50):
            while not q.put_pull(i, timeout=0.02):
                pass
        done.set()

    def consumer():
        while not (done.is_set() and len(q) == 0):
            try:
                item = q.get(timeout=0.02)
            except TimeoutError:
                continue
            if isinstance(item, int) and item < 1000:
                q.put_worker(item + 1000)  # simulate worker reinsert
            else:
                consumed.append(item)

    t1 = threading.Thread(target=producer)
    t2 = threading.Thread(target=consumer)
    t1.start(); t2.start()
    t1.join(timeout=10)
    t2.join(timeout=10)
    assert done.is_set() and len(consumed) == 50


def test_pull_blocked_until_drained():
    q = CentralQueue(capacity=10, lam=0.3)
    for i in range(3):
        q.put_pull(i)
    ok = []

    def delayed_get():
        time.sleep(0.05)
        q.get()

    t = threading.Thread(target=delayed_get)
    t.start()
    ok.append(q.put_pull(99, timeout=1.0))  # unblocks after the get
    t.join()
    assert ok == [True]


def test_close_raises():
    q = CentralQueue()
    q.close()
    with pytest.raises(ClosedError):
        q.put_pull(1)
    with pytest.raises(ClosedError):
        q.get()


def test_close_drains_remaining():
    q = BoundedQueue(4)
    q.put(1); q.put(2)
    q.close()
    assert q.get() == 1 and q.get() == 2
    with pytest.raises(ClosedError):
        q.get()


def test_bounded_queue_capacity():
    q = BoundedQueue(2)
    assert q.try_put(1) and q.try_put(2)
    assert not q.try_put(3)
    q.get()
    assert q.try_put(3)


# --------------------------------------------------------------------------- #
# Sharded central queue: stealing, concurrency stress, close-while-waiting
# --------------------------------------------------------------------------- #
class _Item:
    """Carries a bid so the sharded queue can compute a home stripe."""

    def __init__(self, bid):
        self.bid = bid

    def __repr__(self):
        return f"_Item({self.bid})"


def test_sharded_get_steals_from_longest_sibling():
    q = CentralQueue(capacity=16, lam=1.0, shards=2)
    for i in range(4):
        q.put_worker(_Item(0))  # all on stripe 0 (bid % 2 == 0)
    # consumer 1's own stripe is empty: it must steal rather than time out
    got = q.get(timeout=0.5, shard=1)
    assert got.bid == 0
    assert q.steals == 1


def test_sharded_steal_vs_get_interleaving_no_loss_no_dup():
    """Two consumers racing their own stripes + steals against a producer:
    every item is consumed exactly once."""
    q = CentralQueue(capacity=8, lam=1.0, shards=2)
    N = 300
    consumed = [[], []]
    stop = threading.Event()

    def consumer(idx):
        while not (stop.is_set() and len(q) == 0):
            try:
                consumed[idx].append(q.get(timeout=0.02, shard=idx).bid)
            except TimeoutError:
                continue
            except ClosedError:
                break

    threads = [threading.Thread(target=consumer, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for i in range(N):  # skewed home stripes: ~2/3 of items land on stripe 0
        q.put_worker(_Item(i if i % 3 else 0))
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert sorted(consumed[0] + consumed[1]) == sorted(
        (i if i % 3 else 0) for i in range(N)
    )


def test_watermark_fairness_under_concurrency():
    """Worker reinserts are NEVER blocked by pull ingest pressure: with the
    pull parked at the watermark, concurrent worker reinserts all land
    immediately (the deadlock-prevention invariant, sharded edition)."""
    q = CentralQueue(capacity=10, lam=0.3, shards=2)  # pull limit = 3
    for i in range(3):
        assert q.put_pull(_Item(i), timeout=0.1)

    blocked = threading.Event()

    def pull_ingest():
        blocked.set()
        q.put_pull(_Item(99), timeout=5.0)  # parked at the watermark

    t = threading.Thread(target=pull_ingest)
    t.start()
    blocked.wait(timeout=1.0)

    done = []

    def reinsert(k):
        q.put_worker(_Item(100 + k))
        done.append(k)

    workers = [threading.Thread(target=reinsert, args=(k,)) for k in range(6)]
    t0 = time.monotonic()
    for w in workers:
        w.start()
    for w in workers:
        w.join(timeout=5)
    assert len(done) == 6                    # none of them blocked
    assert time.monotonic() - t0 < 1.0       # ... and none of them waited
    q.get(timeout=0.5, shard=0)              # drain one: pull admitted
    t.join(timeout=5)
    assert not t.is_alive()


def test_close_wakes_pull_blocked_at_watermark():
    q = CentralQueue(capacity=4, lam=0.25, shards=2)  # pull limit = 1
    assert q.put_pull(_Item(0), timeout=0.1)
    results = []

    def blocked_pull():
        try:
            q.put_pull(_Item(1))  # no timeout: a single blocking wait
        except ClosedError:
            results.append("pull-closed")

    t = threading.Thread(target=blocked_pull)
    t.start()
    time.sleep(0.1)  # let it park in the watermark wait
    q.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert results == ["pull-closed"]


def test_close_wakes_getters_on_all_stripes():
    q = CentralQueue(capacity=4, lam=0.25, shards=2)  # empty: getters park
    results = []

    def blocked_get(shard):
        try:
            while True:
                q.get(timeout=10.0, shard=shard)
        except ClosedError:
            results.append(f"get-{shard}-closed")

    threads = [threading.Thread(target=blocked_get, args=(s,)) for s in (0, 1)]
    for t in threads:
        t.start()
    time.sleep(0.1)  # let both park in their stripe waits
    q.close()
    for t in threads:
        t.join(timeout=5)
    assert not any(t.is_alive() for t in threads)
    assert sorted(results) == ["get-0-closed", "get-1-closed"]


def test_sharded_close_drains_before_raising():
    q = CentralQueue(capacity=8, lam=1.0, shards=2)
    q.put_worker(_Item(0))
    q.put_worker(_Item(1))
    q.close()
    got = {q.get(timeout=0.5, shard=0).bid, q.get(timeout=0.5, shard=0).bid}
    assert got == {0, 1}
    with pytest.raises(ClosedError):
        q.get(timeout=0.5, shard=0)


def test_single_shard_queue_is_fifo_across_producers():
    q = CentralQueue(capacity=8, lam=1.0, shards=1)
    q.put_pull("a")
    q.put_worker("b")
    q.put_pull("c")
    assert [q.get(timeout=0.1) for _ in range(3)] == ["a", "b", "c"]
