"""StatsStore: cross-query statistics persistence (core/statstore.py).

Covers warm-start seeding of ``StatsBoard``, age decay (stale profiles
lose to fresh observations), fingerprint stability across processes,
atomic/tolerant persistence, and the executor round-trip."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    AQPExecutor, LayeredReuseCache, SimClock, StatsBoard, StatsStore,
    canonical_fingerprint, fingerprint_of, make_batch,
)
from repro.udfs.synthetic import planted_predicate


def _store(**kw):
    kw.setdefault("clock", lambda: 1000.0)
    return StatsStore(**kw)


def _pred(name="p", cost=0.01, ids=range(50)):
    return planted_predicate(name, ids, cost_per_row=cost)


# ----------------------------- warm start ----------------------------- #
def test_warm_start_seeds_board_measured():
    store = _store()
    p = _pred()
    store.observe(fingerprint_of(p), cost_per_row=0.02, selectivity=0.25)
    board = StatsBoard([p.name])
    assert not board[p.name].measured
    seeded = store.warm_start(board, [p])
    st = board[p.name]
    assert seeded == {p.name: 1}
    assert st.measured            # warmup circulation will be skipped
    assert st.cost() == pytest.approx(0.02)
    assert st.selectivity() == pytest.approx(0.25, abs=0.01)


def test_warm_start_unknown_fingerprint_is_noop():
    store = _store()
    p = _pred()
    board = StatsBoard([p.name])
    assert store.warm_start(board, [p]) == {}
    assert not board[p.name].measured


def test_seed_prior_pseudo_tickets_outvoted_by_fresh_rows():
    """The seed is a bounded prior: fresh lottery rows out-vote it."""
    board = StatsBoard(["p"])
    board.seed_prior("p", cost_per_row=0.01, selectivity=0.9, tickets=100)
    assert board["p"].selectivity() == pytest.approx(0.9)
    # a run that strongly disagrees (10% pass) dominates after ~10x rows
    board["p"].record_eval(1000, 100, seconds=1.0)
    assert board["p"].selectivity() < 0.2


def test_seed_prior_on_sharded_board_merges():
    board = StatsBoard(["p"], shards=4)
    board.seed_prior("p", cost_per_row=0.5, selectivity=0.25, tickets=64)
    st = board["p"]
    assert st.measured
    assert st.cost() == pytest.approx(0.5)
    assert st.selectivity() == pytest.approx(0.25, abs=0.02)


# ------------------------------ decay ------------------------------ #
def test_age_decay_scales_seed_weight():
    now = [0.0]
    store = StatsStore(half_life_s=100.0, pseudo_tickets=200,
                      clock=lambda: now[0])
    p = _pred()
    store.observe(fingerprint_of(p), cost_per_row=0.02, selectivity=0.9)

    now[0] = 100.0  # one half-life: half the pseudo-tickets
    board = StatsBoard([p.name])
    store.warm_start(board, [p])
    assert board[p.name].tickets == 100

    fresh_board = StatsBoard([p.name])
    now[0] = 0.0
    store.warm_start(fresh_board, [p])
    assert fresh_board[p.name].tickets == 200


def test_stale_record_not_seeded_at_all():
    now = [0.0]
    store = StatsStore(half_life_s=10.0, min_weight=0.05,
                      clock=lambda: now[0])
    p = _pred()
    store.observe(fingerprint_of(p), cost_per_row=0.02, selectivity=0.5)
    now[0] = 10.0 * 10  # 10 half-lives: weight ~1e-3 < min_weight
    board = StatsBoard([p.name])
    assert store.warm_start(board, [p]) == {}
    assert not board[p.name].measured


def test_decayed_seed_loses_to_fresh_observations_faster():
    """The headline decay property: an aged profile seeds fewer
    pseudo-tickets, so the same fresh evidence moves the estimate
    further than it would against a fresh seed."""
    now = [0.0]

    def seeded_then_observed(age):
        store = StatsStore(half_life_s=50.0, pseudo_tickets=400,
                           clock=lambda: now[0])
        p = _pred()
        now[0] = 0.0
        store.observe(fingerprint_of(p), cost_per_row=0.02, selectivity=0.9)
        now[0] = age
        board = StatsBoard([p.name])
        store.warm_start(board, [p])
        board[p.name].record_eval(100, 10, seconds=1.0)  # fresh: sel 0.1
        return board[p.name].selectivity()

    assert seeded_then_observed(age=200.0) < seeded_then_observed(age=0.0)


def test_observe_blend_is_age_weighted():
    now = [0.0]
    store = StatsStore(half_life_s=10.0, alpha=0.3, clock=lambda: now[0])
    store.observe("fp", cost_per_row=1.0, selectivity=0.5)
    now[0] = 1000.0  # ancient: the re-observation should dominate
    store.observe("fp", cost_per_row=3.0, selectivity=0.1)
    rec = store.get("fp")
    assert rec["cost_per_row"] == pytest.approx(3.0, rel=0.01)
    assert rec["selectivity"] == pytest.approx(0.1, abs=0.01)


# --------------------------- fingerprints --------------------------- #
def test_fingerprint_deterministic_and_config_sensitive():
    a = canonical_fingerprint("hsv_color", color="black", size=64)
    assert a == canonical_fingerprint("hsv_color", size=64, color="black")
    assert a != canonical_fingerprint("hsv_color", color="white", size=64)
    assert a != canonical_fingerprint("hsv_color", color="black", size=64,
                                      version=2)
    assert "cmv=" in a


def test_fingerprint_stable_across_processes():
    """Fingerprints must not depend on process-randomized hashing."""
    code = (
        "from repro.udfs.library import color_predicate\n"
        "from repro.core import fingerprint_of\n"
        "print(fingerprint_of(color_predicate('black', size=64)))\n"
    )
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, check=True)
    from repro.udfs.library import color_predicate

    assert out.stdout.strip() == fingerprint_of(color_predicate("black",
                                                                size=64))


def test_fingerprint_fallback_for_adhoc_udf():
    p = _pred("adhoc")
    q = _pred("adhoc")
    assert fingerprint_of(p) == fingerprint_of(q)
    assert fingerprint_of(p) != fingerprint_of(_pred("other"))


# --------------------------- persistence --------------------------- #
def test_store_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "stats.json")
    store = _store(path=path)
    store.observe("fp", cost_per_row=0.5, selectivity=0.75, batches=3)
    store.flush()
    store2 = _store(path=path)
    rec = store2.get("fp")
    assert rec["cost_per_row"] == 0.5
    assert rec["selectivity"] == 0.75
    assert rec["batches"] == 3


def test_store_corrupt_file_starts_cold(tmp_path):
    path = os.path.join(tmp_path, "stats.json")
    with open(path, "w") as f:
        f.write("{ not json")
    with pytest.warns(UserWarning, match="starting cold"):
        store = _store(path=path)
    assert len(store) == 0
    store.observe("fp", cost_per_row=1.0, selectivity=0.5)
    store.flush()
    assert _store(path=path).get("fp") is not None


def test_store_flush_atomic(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "stats.json")
    store = _store(path=path)
    store.observe("fp", cost_per_row=1.0, selectivity=0.5)
    store.flush()

    real_replace = os.replace

    def boom(*a):
        raise OSError("yanked")

    monkeypatch.setattr(os, "replace", boom)
    store.observe("fp2", cost_per_row=2.0, selectivity=0.5)
    with pytest.raises(OSError):
        store.flush()
    monkeypatch.setattr(os, "replace", real_replace)
    blob = json.load(open(path))
    assert "fp" in blob["records"] and "fp2" not in blob["records"]


def test_record_board_skips_seed_only_entries():
    """A run that never profiled anything must not refresh updated_at."""
    now = [0.0]
    store = StatsStore(clock=lambda: now[0])
    p = _pred()
    store.observe(fingerprint_of(p), cost_per_row=0.02, selectivity=0.5)
    now[0] = 500.0
    board = StatsBoard([p.name])
    seeded = store.warm_start(board, [p])
    store.record_board(board, [p], seeded=seeded)  # nothing new observed
    assert store.get(fingerprint_of(p))["updated_at"] == 0.0
    board[p.name].record_eval(10, 5, seconds=0.1)  # now something real
    store.record_board(board, [p], seeded=seeded)
    assert store.get(fingerprint_of(p))["updated_at"] == 500.0


# --------------------------- executor glue --------------------------- #
def _run_query(store, cache=None):
    p1 = planted_predicate("sq_a", range(0, 60), cost_per_row=0.01)
    p2 = planted_predicate("sq_b", range(30, 90), cost_per_row=0.03,
                           resource="tpu:1")
    src = [make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
           for i in range(0, 100, 10)]
    ex = AQPExecutor([p1, p2], clock=SimClock(), max_workers=1,
                     cache=cache, stats_store=store)
    got = set()
    for b in ex.run(iter(src)):
        got |= {int(i) for i in b.row_ids}
    assert got == set(range(30, 60))
    return ex


def test_executor_roundtrip_warm_starts_second_run(tmp_path):
    path = os.path.join(tmp_path, "stats.json")
    store = StatsStore(path)
    _run_query(store)
    rec = store.get(canonical_fingerprint("planted:sq_b",
                                          cost_per_row=0.03, column="rid"))
    assert rec is not None
    assert rec["cost_per_row"] == pytest.approx(0.03, rel=0.2)
    assert os.path.exists(path)  # shutdown flushed

    # a NEW store (fresh process analogue) warm-starts the next executor
    store2 = StatsStore(path)
    p1 = planted_predicate("sq_a", range(0, 60), cost_per_row=0.01)
    p2 = planted_predicate("sq_b", range(30, 90), cost_per_row=0.03,
                           resource="tpu:1")
    ex = AQPExecutor([p1, p2], clock=SimClock(), max_workers=1,
                     stats_store=store2)
    assert ex.stats["sq_a"].measured and ex.stats["sq_b"].measured
    assert ex.stats["sq_b"].cost() == pytest.approx(0.03, rel=0.2)
    ex.shutdown()


def test_executor_with_layered_cache_and_store(tmp_path):
    """Smoke the full tentpole stack through one executor."""
    store = StatsStore(os.path.join(tmp_path, "s.json"))
    cache = LayeredReuseCache(os.path.join(tmp_path, "c.npz"))
    _run_query(store, cache=cache)
    assert cache.size("sq_a") > 0 or cache.size("sq_b") > 0
