"""Data pipeline: determinism, resumability, prefetch backpressure,
synthetic video/text ground truth."""
import numpy as np

from repro.data.pipeline import Prefetcher, TokenSource, shard_batch
from repro.data.text import make_reviews, topic_of_tokens
from repro.data.video import SyntheticVideo, crop_to_canonical


def test_token_source_deterministic():
    a = TokenSource(100, 16, seed=5)
    b = TokenSource(100, 16, seed=5)
    for _ in range(3):
        ba, bb = a.next(4), b.next(4)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_token_source_resumable():
    a = TokenSource(100, 16, seed=5)
    a.next(4)
    state = a.state()
    want = a.next(4)
    b = TokenSource(100, 16, seed=5)
    b.restore(state)
    got = b.next(4)
    np.testing.assert_array_equal(want["tokens"], got["tokens"])


def test_labels_are_shifted_tokens():
    s = TokenSource(100, 16, seed=1)
    b = s.next(2)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)


def test_prefetcher_order_and_stop():
    src = iter(range(100))
    pf = Prefetcher(lambda: next(src), depth=2)
    got = [pf.next() for _ in range(10)]
    assert got == list(range(10))
    pf.stop()


def test_prefetcher_propagates_errors():
    def boom():
        raise ValueError("producer died")

    pf = Prefetcher(boom, depth=1)
    import pytest

    with pytest.raises(ValueError, match="producer died"):
        pf.next()


def test_shard_batch_no_mesh():
    out = shard_batch({"tokens": np.ones((4, 8), np.int32)})
    assert out["tokens"].shape == (4, 8)


def test_video_ground_truth_consistency():
    v = SyntheticVideo(num_frames=50, seed=1)
    gt = v.ground_truth("great dane", "black")
    for o in gt:
        assert o.breed == "great dane" and o.color == "black"
    # planted rectangles really are dark (black dogs)
    for o in gt[:3]:
        crop = v.crop(o.frame_id, o.bbox)
        assert crop.mean() < 60


def test_crop_canonicalization():
    v = SyntheticVideo(num_frames=5, seed=0)
    dogs = [o for o in v.objects if o.label == "dog"]
    c = crop_to_canonical(v.crop(dogs[0].frame_id, dogs[0].bbox), 64)
    assert c.shape == (64, 64, 3)


def test_reviews_topic_oracle():
    reviews = make_reviews(100, seed=2)
    agree = sum(topic_of_tokens(r.tokens) == r.topic for r in reviews)
    assert agree >= 95  # generator plants a clear majority signal
    lengths = [len(r.tokens) for r in reviews]
    assert max(lengths) > 4 * min(lengths)  # heavy-tailed (Fig 13 driver)
