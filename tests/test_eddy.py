"""Eddy router behaviour: warmup fan-out + circular flow, completion
accounting, metadata-driven skip of visited predicates (§3.3/§4.1)."""
import numpy as np

from repro.core import AQPExecutor, CostDriven, Predicate, UDF, make_batch


def _pred(name, fn, resource="cpu", cost=None):
    udf = UDF(name + "_udf", fn=fn, columns=("x",), resource=resource,
              cost_model=cost)
    return Predicate(name, udf, compare=lambda out: out.astype(bool))


def _batches(n_rows, per=10):
    return [
        make_batch({"x": np.arange(i, i + per, dtype=np.float64)},
                   np.arange(i, i + per))
        for i in range(0, n_rows, per)
    ]


def test_warmup_measures_all_predicates():
    pa = _pred("a", lambda d: d["x"] >= 0)
    pb = _pred("b", lambda d: d["x"] >= 0)
    pc = _pred("c", lambda d: d["x"] >= 0)
    ex = AQPExecutor([pa, pb, pc], policy=CostDriven(), max_workers=2)
    out = ex.collect(iter(_batches(100)))
    snap = ex.stats_snapshot()
    assert all(snap[p]["batches"] > 0 for p in ("a", "b", "c"))
    got = {int(i) for b in out for i in b.row_ids}
    assert got == set(range(100))


def test_warmup_circular_flow_counts():
    """With slow predicates, some batches must circulate during warmup."""
    import time

    def slow(d):
        time.sleep(0.02)
        return d["x"] >= 0

    pa = _pred("a", slow)
    pb = _pred("b", slow)
    ex = AQPExecutor([pa, pb], policy=CostDriven(), max_workers=1)
    out = ex.collect(iter(_batches(80)))
    assert {int(i) for b in out for i in b.row_ids} == set(range(80))
    assert ex._router.circulations > 0  # delayed batches circulated


def test_visited_metadata_no_double_eval():
    """Each predicate sees each row at most once (visited-set skip)."""
    seen = {"a": [], "b": []}

    def mk(name):
        def fn(d):
            seen[name].extend(d["x"].tolist())
            return d["x"] >= 0
        return fn

    pa = _pred("a", mk("a"))
    pb = _pred("b", mk("b"))
    ex = AQPExecutor([pa, pb], policy=CostDriven(), max_workers=2)
    ex.collect(iter(_batches(60)))
    # bucketing pads batches with repeated row 0 — count unique ids
    assert len(set(seen["a"])) == 60 and len(set(seen["b"])) == 60
    # no row evaluated twice by the same predicate (modulo bucket padding,
    # which only ever repeats a batch's FIRST row: 10 rows -> bucket 16)
    for name in ("a", "b"):
        vals, counts = np.unique(np.asarray(seen[name]), return_counts=True)
        nonfirst = counts[np.isin(vals, np.arange(60)) & (vals % 10 != 0)]
        assert (nonfirst == 1).all()
        first = counts[vals % 10 == 0]
        assert (first <= 1 + 6).all()  # row + up to 6 pad repeats


def test_empty_batches_complete():
    """Batches emptied by eager materialization finish without output rows."""
    pa = _pred("a", lambda d: d["x"] < 0)  # drops everything
    pb = _pred("b", lambda d: d["x"] >= 0)
    ex = AQPExecutor([pa, pb], policy=CostDriven(), max_workers=2)
    out = ex.collect(iter(_batches(50)))
    assert out == []


def test_worker_exception_propagates():
    def boom(d):
        raise ValueError("kaboom")

    pa = _pred("a", boom)
    ex = AQPExecutor([pa], max_workers=1, warmup=False)
    import pytest

    with pytest.raises(RuntimeError, match="predicate worker failed"):
        ex.collect(iter(_batches(10)))
