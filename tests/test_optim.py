"""Optimizer + gradient-compression tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamW, Adafactor, Int8ErrorFeedback, compressed_psum, constant_schedule,
    cosine_schedule,
)
from repro.optim.compression import quantize_dequantize


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)

    params = {"w": jnp.zeros(3), "b": jnp.zeros(2)}
    return loss, params


def _optimize(opt, steps=200):
    loss, params = _quad_problem()
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    return float(loss(params))


def test_adamw_converges():
    assert _optimize(AdamW(schedule=constant_schedule(0.05))) < 1e-2


def test_adamw_bf16_moments_converge():
    opt = AdamW(schedule=constant_schedule(0.05), moment_dtype="bfloat16")
    assert _optimize(opt) < 5e-2


def test_adafactor_converges():
    assert _optimize(Adafactor(schedule=constant_schedule(0.1)), 300) < 5e-2


def test_int8_error_feedback_converges():
    opt = Int8ErrorFeedback(AdamW(schedule=constant_schedule(0.05)))
    assert _optimize(opt) < 5e-2


def test_adamw_matches_reference_math():
    """One AdamW step vs hand-computed update."""
    opt = AdamW(schedule=constant_schedule(0.1), b1=0.9, b2=0.99,
                eps=1e-8, clip_norm=0.0)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.5])}
    upd, state = opt.update(g, opt.init(p), p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = -0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(upd["w"][0]), expect, rtol=1e-5)


def test_grad_clipping():
    opt = AdamW(schedule=constant_schedule(1.0), clip_norm=1.0)
    p = {"w": jnp.asarray([0.0])}
    g = {"w": jnp.asarray([1e6])}
    upd, _ = opt.update(g, opt.init(p), p)
    assert np.isfinite(float(upd["w"][0]))


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(55)) < 1.0
    assert float(s(100)) >= 0.1 - 1e-6  # floor


def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    xq = quantize_dequantize(x)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(xq - x))) <= amax / 127.0 + 1e-6


def test_compressed_psum_single_device():
    import jax
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray([1.0, -2.0, 3.0])

    def f(v):
        total, n = compressed_psum(v, "data")
        return total / n

    out = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(None),
                                out_specs=P(None), check_vma=False))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=0.02, atol=0.02)


def test_optimizer_state_shapes_match_init():
    for opt in (AdamW(), Adafactor(), Int8ErrorFeedback(AdamW())):
        p = {"w": jnp.zeros((4, 3)), "b": jnp.zeros((3,))}
        state = opt.init(p)
        shapes = opt.state_shapes(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), p)
        )
        real = jax.tree.map(lambda x: (x.shape, str(x.dtype)), state)
        spec = jax.tree.map(lambda s: (s.shape, str(s.dtype)), shapes)
        assert real == spec
