"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = dict(rtol=2e-2, atol=2e-2)
TOL_TIGHT = dict(rtol=1e-4, atol=1e-5)


def ok(a, b, tol=TOL):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **tol
    )


# ------------------------------ flash attention --------------------------- #
@pytest.mark.parametrize("b,s,h,hkv,d", [
    (1, 128, 4, 4, 32),    # MHA
    (2, 256, 4, 2, 64),    # GQA
    (1, 256, 8, 1, 64),    # MQA
    (2, 200, 4, 2, 32),    # non-block-multiple seq (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(rng, b, s, h, hkv, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    tol = TOL if dtype == jnp.float32 else dict(rtol=8e-2, atol=8e-2)
    ok(ops.flash_attention(q, k, v, impl="pallas"),
       ops.flash_attention(q, k, v, impl="xla"), tol)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_sliding_window(rng, window):
    b, s, h, hkv, d = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    ok(ops.flash_attention(q, k, v, window=window, impl="pallas"),
       ops.flash_attention(q, k, v, window=window, impl="xla"))


def test_xla_chunked_matches_dense(rng):
    """The memory-bounded chunked XLA path is exact vs dense."""
    b, s, h, hkv, d = 1, 1024, 2, 1, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    dense = ref.mha_attention(q, k, v, chunk_q=0)
    chunked = ref.mha_attention(q, k, v, chunk_q=256)
    unrolled = ref.mha_attention(q, k, v, chunk_q=256, unroll=True)
    ok(chunked, dense, TOL_TIGHT)
    ok(unrolled, dense, TOL_TIGHT)


def test_xla_chunked_swa_banded(rng):
    b, s, h, hkv, d = 1, 1024, 2, 1, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    dense = ref.mha_attention(q, k, v, window=128, chunk_q=0)
    banded = ref.mha_attention(q, k, v, window=128, chunk_q=256)
    ok(banded, dense, TOL_TIGHT)


# ------------------------------ decode attention -------------------------- #
@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 512, 4, 2, 64), (1, 256, 8, 8, 32), (3, 512, 8, 1, 64),
])
def test_decode_attention(rng, b, s, h, hkv, d):
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    ok(ops.decode_attention(q, kc, vc, lens, impl="pallas"),
       ops.decode_attention(q, kc, vc, lens, impl="xla"))


def test_decode_attention_matches_full(rng):
    """Decode vs full attention at the last position."""
    b, s, h, hkv, d = 2, 128, 4, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    full = ref.mha_attention(q, k, v, causal=True)[:, -1]
    dec = ref.decode_attention(q[:, -1], k, v, jnp.full((b,), s, jnp.int32))
    ok(dec, full, TOL_TIGHT)


# ------------------------------ RG-LRU ------------------------------------ #
@pytest.mark.parametrize("b,s,w", [(1, 64, 64), (2, 128, 128), (2, 96, 256)])
def test_rglru(rng, b, s, w):
    x = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    i = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((w,)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((b, w)), jnp.float32)
    o1, h1 = ops.rglru(x, r, i, a, h0, impl="xla")
    o2, h2 = ops.rglru(x, r, i, a, h0, impl="pallas", block_s=32, block_w=64)
    ok(o2, o1)
    ok(h2, h1)


def test_rglru_state_chaining(rng):
    """Running two halves with state == running the whole sequence."""
    b, s, w = 2, 64, 32
    x = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    i = jnp.asarray(rng.standard_normal((b, s, w)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((w,)), jnp.float32)
    o_full, h_full = ref.rglru(x, r, i, a)
    o1, h1 = ref.rglru(x[:, :32], r[:, :32], i[:, :32], a)
    o2, h2 = ref.rglru(x[:, 32:], r[:, 32:], i[:, 32:], a, h1)
    ok(jnp.concatenate([o1, o2], 1), o_full, TOL_TIGHT)
    ok(h2, h_full, TOL_TIGHT)


# ------------------------------ SSD (mamba2) ------------------------------- #
@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 16, 16),
    (2, 128, 4, 32, 2, 16, 32),
    (1, 128, 4, 64, 1, 32, 64),
])
def test_ssd(rng, b, s, h, p, g, n, chunk):
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y1, hl1 = ops.ssd(x, dt, A, B, C, h0, chunk=chunk, impl="xla")
    y2, hl2 = ops.ssd(x, dt, A, B, C, h0, chunk=chunk, impl="pallas")
    ok(y2, y1, dict(rtol=3e-2, atol=3e-2))
    ok(hl2, hl1, dict(rtol=3e-2, atol=3e-2))


def test_ssd_chunk_invariance(rng):
    """Chunk size is an implementation detail: results must not change."""
    b, s, h, p, g, n = 1, 128, 2, 16, 1, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    y1, h1 = ref.ssd(x, dt, A, B, C, chunk=32)
    y2, h2 = ref.ssd(x, dt, A, B, C, chunk=64)
    ok(y1, y2, TOL)
    ok(h1, h2, TOL)


def test_ssd_decode_consistency(rng):
    """Recurrent decode step == last position of the chunked scan."""
    b, s, h, p, g, n = 1, 65, 2, 16, 1, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    y_pre, h_pre = ref.ssd(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64], chunk=32)
    y_step, h_step = ref.ssd_decode_step(
        x[:, 64], dt[:, 64], A, B[:, 64], C[:, 64], h_pre
    )
    # full scan over 65 requires chunk divisibility; compare via 1-chunk run
    y_full, h_full = ref.ssd(
        x[:, 64:65], dt[:, 64:65], A, B[:, 64:65], C[:, 64:65], h_pre, chunk=1
    )
    ok(y_step, y_full[:, 0], TOL)
    ok(h_step, h_full, TOL)


# ------------------------------ HSV color --------------------------------- #
@pytest.mark.parametrize("b,h,w", [(2, 32, 16), (4, 64, 64), (1, 96, 48)])
def test_hsv_color(rng, b, h, w):
    crops = jnp.asarray(rng.uniform(0, 255, (b, h, w, 3)), jnp.float32)
    h1, l1 = ops.hsv_color_classify(crops, impl="xla")
    h2, l2 = ops.hsv_color_classify(crops, impl="pallas", block_rows=16)
    ok(h2, h1, TOL_TIGHT)
    assert (np.asarray(l1) == np.asarray(l2)).all()


def test_hsv_known_colors():
    """Solid-color crops classify to their color (paper's HSV table)."""
    solid = {
        "black": (5, 5, 5), "white": (250, 250, 250), "red": (220, 30, 30),
        "green": (40, 200, 40), "blue": (40, 60, 220), "yellow": (230, 220, 30),
    }
    crops = np.zeros((len(solid), 16, 16, 3), np.float32)
    for i, rgb in enumerate(solid.values()):
        crops[i] = np.asarray(rgb, np.float32)
    _, labels = ops.hsv_color_classify(jnp.asarray(crops), impl="xla")
    got = [ref.COLOR_NAMES[int(i)] for i in np.asarray(labels)]
    assert got == list(solid), got


# ------------------------------ MoE router --------------------------------- #
@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (128, 16, 2), (32, 4, 1)])
def test_moe_router(rng, t, e, k):
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    w1, i1 = ops.moe_topk_router(logits, k, impl="xla")
    w2, i2 = ops.moe_topk_router(logits, k, impl="pallas", block_t=16)
    ok(w2, w1, TOL_TIGHT)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    # weights renormalized
    np.testing.assert_allclose(np.asarray(w1.sum(-1)), 1.0, rtol=1e-5)
