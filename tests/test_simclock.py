"""Discrete-event simulated clock semantics (core/simclock.py)."""
from repro.core.simclock import SimClock, WallClock


def test_occupy_serializes_per_resource():
    c = SimClock()
    assert c.occupy("r", 2.0) == 2.0
    assert c.occupy("r", 3.0) == 5.0
    assert c.makespan == 5.0


def test_occupy_shared_overlap_and_ready():
    c = SimClock()
    # two workers overlap fully when serial_fraction=0
    f1 = c.occupy_shared("w1", "dev", 4.0, 0.0, ready=0.0)
    f2 = c.occupy_shared("w2", "dev", 4.0, 0.0, ready=0.0)
    assert f1 == 4.0 and f2 == 4.0

    # serial_fraction=0.5 gates the device: third job waits for dev horizon
    c2 = SimClock()
    c2.occupy_shared("a", "dev", 4.0, 0.5, ready=0.0)   # dev busy to 2
    c2.occupy_shared("b", "dev", 4.0, 0.5, ready=0.0)   # starts at 2
    f = c2.occupy_shared("c", "dev", 4.0, 0.5, ready=0.0)
    assert f == 4.0 + 4.0  # start 4 (dev free), +4


def test_ready_time_not_global_now():
    """Virtual start uses the batch's ready time, NOT the advanced clock —
    thread interleaving must not distort the timeline."""
    c = SimClock()
    c.occupy_shared("w1", "d1", 10.0, 0.0, ready=0.0)   # now = 10
    f = c.occupy_shared("w2", "d2", 1.0, 0.0, ready=2.0)
    assert f == 3.0  # starts at its ready time, not at now=10


def test_busy_time_accounting():
    c = SimClock()
    c.occupy_shared("w", "dev", 4.0, 0.25, ready=0.0)
    assert c.busy_time("w") == 4.0
    assert c.busy_time("dev") == 1.0


def test_wallclock_monotonic():
    w = WallClock()
    a = w.now()
    w.sleep(0.001)
    assert w.now() >= a
