"""TPU-native on-device short-circuit (core/vectorized.py) — exactness vs
naive evaluation across selectivities, and the compute-saving property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vectorized import cascade_filter, compact_indices, two_stage_filter


def test_compact_indices():
    mask = jnp.asarray([True, False, True, True, False])
    idx = compact_indices(mask, 3)
    np.testing.assert_array_equal(np.asarray(idx), [0, 2, 3])
    idx2 = compact_indices(mask, 5)
    np.testing.assert_array_equal(np.asarray(idx2), [0, 2, 3, 5, 5])  # sentinel pad


@pytest.mark.parametrize("thresh_a,thresh_b", [
    (-2.0, 0.0), (0.0, 0.5), (1.0, -1.0), (2.5, 2.5),
])
@pytest.mark.parametrize("frac", [0.25, 0.5, 1.0])
def test_two_stage_exact(rng, thresh_a, thresh_b, frac):
    x = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
    cheap = lambda v: v.sum(-1) > thresh_a
    expensive = lambda v: (v * v).sum(-1) - 4.0 > thresh_b
    naive = np.asarray(cheap(x) & expensive(x))
    got = np.asarray(jax.jit(
        lambda xx: two_stage_filter(cheap, expensive, xx, bucket_fraction=frac)
    )(x))
    np.testing.assert_array_equal(got, naive)


def test_cascade_exact(rng):
    x = jnp.asarray(rng.standard_normal((128, 4)), jnp.float32)
    fns = [
        lambda v: v.sum(-1) > -1.0,
        lambda v: v[:, 0] > 0.0,
        lambda v: (v * v).sum(-1) > 2.0,
    ]
    naive = np.asarray(fns[0](x) & fns[1](x) & fns[2](x))
    got = np.asarray(jax.jit(lambda xx: cascade_filter(fns, xx))(x))
    np.testing.assert_array_equal(got, naive)


def test_two_stage_evaluates_fewer_rows(rng):
    """The expensive fn sees at most 2*bucket rows (compute saving)."""
    calls = {"rows": 0}

    x = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
    cheap = lambda v: v.sum(-1) > 1.5  # very selective

    def expensive(v):
        calls["rows"] += v.shape[0]  # static shape — trace-time accounting
        return (v * v).sum(-1) > 0.0

    _ = two_stage_filter(cheap, expensive, x, bucket_fraction=0.25)
    assert calls["rows"] <= 2 * 16  # two bucket passes max, not 64
