"""Roofline accounting units: HLO collective parsing, ring multipliers,
delta totals, analytic model FLOPs."""
import numpy as np
import pytest

from repro.configs import get_config, get_shape
from repro.roofline import analysis, hw

HLO = """
ENTRY %main {
  %ar = bf16[16,688]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[256,1024]{1,0} all-gather(%y), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%u, %v), replica_groups={{0,1}}
  %cp = bf16[32]{0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %ars = bf16[16,688]{1,0} all-reduce-start(%x2), replica_groups={{0,1,2,3}}
}
"""


def test_parse_collectives_counts_and_bytes():
    out = analysis.parse_collectives(HLO, default_group=256)
    assert out["all-reduce"]["count"] == 2           # incl. -start form
    assert out["all-gather"]["count"] == 1
    assert out["reduce-scatter"]["count"] == 1
    assert out["all-to-all"]["count"] == 1
    assert out["collective-permute"]["count"] == 1
    # all-reduce result bytes: 16*688*2 each
    assert out["all-reduce"]["result_bytes"] == 2 * 16 * 688 * 2
    # group sizes: AR group 4 -> wire = 2*(3/4)*R
    ar_r = 16 * 688 * 2
    assert out["all-reduce"]["wire_bytes"] == pytest.approx(2 * ar_r * 2 * 3 / 4)
    # all-gather iota group [16,16] -> size 16
    ag_r = 256 * 1024 * 4
    assert out["all-gather"]["wire_bytes"] == pytest.approx(ag_r * 15 / 16)
    # reduce-scatter: wire = R*(n-1), n=8
    rs_r = 8 * 128 * 2
    assert out["reduce-scatter"]["wire_bytes"] == pytest.approx(rs_r * 7)
    # tuple all-to-all: both result tensors counted
    assert out["all-to-all"]["result_bytes"] == 2 * 4 * 4 * 4


def test_wire_multiplier_degenerate_group():
    assert analysis.wire_multiplier("all-reduce", 1) == 0.0
    assert analysis.wire_multiplier("collective-permute", 4) == 1.0


def test_delta_total():
    base = analysis.CostSample(flops=10.0, bytes_accessed=100.0, wire_bytes=5.0)
    unit = analysis.CostSample(flops=13.0, bytes_accessed=140.0, wire_bytes=7.0)
    tot = analysis.delta_total(base, [(32, unit)])
    assert tot["flops"] == 10 + 32 * 3
    assert tot["bytes"] == 100 + 32 * 40
    assert tot["wire"] == 5 + 32 * 2


def test_roofline_terms_dominance():
    t = analysis.roofline_terms(hw.PEAK_FLOPS_BF16, 0.0, 0.0)
    assert t["dominant"] == "compute_s" and t["roofline_fraction"] == 1.0
    t2 = analysis.roofline_terms(hw.PEAK_FLOPS_BF16, hw.HBM_BW * 2, 0.0)
    assert t2["dominant"] == "memory_s"
    assert t2["roofline_fraction"] == pytest.approx(0.5)


def test_model_flops_kinds():
    cfg = get_config("llama3-8b")
    train = analysis.model_flops(cfg, get_shape("train_4k"))
    prefill = analysis.model_flops(cfg, get_shape("prefill_32k"))
    decode = analysis.model_flops(cfg, get_shape("decode_32k"))
    assert train == pytest.approx(3 * prefill)       # same tokens, 6ND vs 2ND
    assert decode < prefill / 1000                   # one token per sequence


def test_moe_model_flops_uses_active():
    cfg = get_config("arctic-480b")
    from repro.models.registry import model_api

    mf = analysis.model_flops(cfg, get_shape("train_4k"))
    n_act = model_api(cfg).active_param_count(cfg)
    assert mf == pytest.approx(6.0 * n_act * get_shape("train_4k").tokens)
