"""Fault-tolerance layer (core/faults.py + executor ``on_fault=``):
deterministic injection schedules, pinned SimClock retry/backoff
timelines, poison-batch and predicate quarantine, degrade-to-fallback,
failure-aware routing, launch watchdog, and the teardown/termination
regressions (tracker decrement on error paths, shard error no-hang,
context-manager shutdown)."""
import threading
import time
import types
from collections import Counter

import numpy as np
import pytest

from repro.core import (
    AQPExecutor,
    CostDriven,
    FaultConfig,
    FaultLedger,
    FaultPlan,
    InjectedFault,
    LaunchWatchdog,
    Predicate,
    ReuseCache,
    SimClock,
    UDF,
    WallClock,
    make_batch,
)
from repro.core.faults import backoff_delay
from repro.core.stats import StatsBoard
from repro.udfs.synthetic import planted_predicate


def _pred(name, fn, resource="cpu", cost=None, fallback=None):
    udf = UDF(name + "_udf", fn=fn, columns=("x",), resource=resource,
              cost_model=cost, fallback_fn=fallback)
    return Predicate(name, udf, compare=lambda out: out.astype(bool))


def _batches(n_rows, per=10):
    return [
        make_batch({"x": np.arange(i, i + per, dtype=np.float64)},
                   np.arange(i, i + per))
        for i in range(0, n_rows, per)
    ]


def _rid_batches(n_rows, per=4):
    return [
        make_batch({"rid": np.arange(i, i + per)}, np.arange(i, i + per))
        for i in range(0, n_rows, per)
    ]


def _multiset(batches):
    return Counter(int(i) for b in batches for i in b.row_ids)


def _collect_with_timeout(ex, source, timeout=30.0):
    """Run collect() on a helper thread so a termination-barrier
    regression FAILS the test instead of hanging the session."""
    result = {}

    def go():
        try:
            result["out"] = ex.collect(source)
        except BaseException as e:  # re-raised on the test thread
            result["err"] = e

    t = threading.Thread(target=go, daemon=True)
    t.start()
    t.join(timeout)
    assert not t.is_alive(), "executor did not terminate (hung run)"
    if "err" in result:
        raise result["err"]
    return result["out"]


# ------------------------------------------------------------------ #
# FaultPlan: deterministic schedules
# ------------------------------------------------------------------ #
def test_fault_plan_attempt_schedule_and_counters():
    plan = FaultPlan().fail("a", attempts=(1, 3))
    p = _pred("a", lambda d: d["x"] >= 0)
    data = {"x": np.arange(4, dtype=np.float64)}
    clock = WallClock()
    with pytest.raises(InjectedFault):
        plan.invoke(p, data, clock)          # attempt 1: scheduled
    out = plan.invoke(p, data, clock)        # attempt 2: clean
    assert np.asarray(out).dtype == bool
    with pytest.raises(InjectedFault):
        plan.invoke(p, data, clock)          # attempt 3: scheduled
    assert plan.attempt_count("a") == 3
    assert plan.injected == 2


def test_fault_plan_probabilistic_schedule_is_seeded():
    def schedule(seed):
        plan = FaultPlan(seed=seed).fail("p", probability=0.3)
        spec = plan._specs["p"][0]
        return [spec.triggers(a) for a in range(1, 41)]

    s1 = schedule(7)
    assert s1 == schedule(7)                 # bit-exact run to run
    assert any(s1) and not all(s1)           # actually probabilistic
    assert s1 != schedule(8)                 # seed matters


def test_injected_hang_is_virtual_under_simclock():
    plan = FaultPlan().hang("a", attempts=(1,), seconds=2.5)
    p = _pred("a", lambda d: d["x"] >= 0)
    data = {"x": np.arange(4, dtype=np.float64)}
    t0 = time.perf_counter()
    plan.invoke(p, data, SimClock())
    assert time.perf_counter() - t0 < 1.0    # no wall sleep
    assert plan.take_extra_cost() == 2.5     # deposited as virtual cost
    assert plan.take_extra_cost() == 0.0     # consumed exactly once


# ------------------------------------------------------------------ #
# FaultConfig / backoff
# ------------------------------------------------------------------ #
def test_fault_config_resolution():
    assert FaultConfig.resolve(None) is None
    assert FaultConfig.resolve("fail_fast") is None
    assert FaultConfig.resolve("retry").mode == "retry"
    assert FaultConfig.resolve("degrade").mode == "degrade"
    custom = FaultConfig(mode="retry", max_attempts=9)
    assert FaultConfig.resolve(custom) is custom
    with pytest.raises(ValueError):
        FaultConfig.resolve("explode")
    with pytest.raises(ValueError):
        FaultConfig(mode="nope")
    with pytest.raises(ValueError):
        FaultConfig(max_attempts=0)


def test_backoff_delay_caps_and_jitter_bounds():
    cfg = FaultConfig(mode="retry", backoff_base_s=0.1, backoff_cap_s=0.25,
                      jitter=0.0)
    rng = np.random.default_rng(0)
    assert backoff_delay(cfg, 1, rng) == 0.1
    assert backoff_delay(cfg, 2, rng) == 0.2
    assert backoff_delay(cfg, 3, rng) == 0.25      # capped
    assert backoff_delay(cfg, 10, rng) == 0.25
    jit = FaultConfig(mode="retry", backoff_base_s=0.1, backoff_cap_s=1.0,
                      jitter=0.5)
    ds = [backoff_delay(jit, 1, np.random.default_rng(s)) for s in range(20)]
    assert all(0.1 <= d <= 0.15 + 1e-12 for d in ds)
    assert len(set(ds)) > 1                        # jitter varies by stream
    zero = FaultConfig(mode="retry", backoff_base_s=0.0)
    assert backoff_delay(zero, 5, rng) == 0.0


# ------------------------------------------------------------------ #
# Retry: pinned SimClock timelines
# ------------------------------------------------------------------ #
def test_retry_backoff_timeline_pinned_simclock():
    p = planted_predicate("P", range(100), cost_per_row=0.01)
    plan = FaultPlan().fail("P", attempts=(1, 2))
    cfg = FaultConfig(mode="retry", max_attempts=5, backoff_base_s=0.1,
                      backoff_cap_s=1.0, jitter=0.0)
    ex = AQPExecutor([p], clock=SimClock(), policy=CostDriven(),
                     max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan)
    out = ex.collect(iter(_rid_batches(4, per=4)))
    assert _multiset(out) == Counter(range(4))
    # attempt 1 fails PRE-launch (no virtual cost), backoff 0.1; attempt 2
    # fails, backoff 0.2; attempt 3 launches: 4 rows x 0.01 occupancy
    assert ex.makespan == pytest.approx(0.1 + 0.2 + 0.04, abs=1e-9)
    f = ex.stats_snapshot()["_faults"]["P"]
    assert f["failures"] == 2 and f["retries"] == 2 and f["successes"] == 1
    assert f["consecutive_failures"] == 0 and not f["quarantined"]
    assert all(not b.passthrough for b in out)


def test_seeded_jitter_run_to_run_deterministic():
    def run():
        p = planted_predicate("P", range(100), cost_per_row=0.01)
        plan = FaultPlan(seed=3).fail("P", probability=0.4)
        cfg = FaultConfig(mode="retry", max_attempts=4, backoff_base_s=0.05,
                          backoff_cap_s=0.4, jitter=0.5, seed=11)
        ex = AQPExecutor([p], clock=SimClock(), policy=CostDriven(),
                         max_workers=1, warmup=False, on_fault=cfg,
                         fault_plan=plan)
        out = ex.collect(iter(_rid_batches(12, per=4)))
        return (ex.makespan, sorted(_multiset(out).items()),
                ex.stats_snapshot()["_faults"]["P"])

    a, b = run(), run()
    assert a == b                  # injected timeline is bit-exact
    assert a[2]["failures"] > 0    # ... and faults actually fired


def test_virtual_hang_and_posthoc_deadline():
    p = planted_predicate("P", range(100), cost_per_row=0.01)
    plan = FaultPlan().hang("P", attempts=(1,), seconds=5.0)
    cfg = FaultConfig(mode="retry", launch_deadline_s=1.0,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([p], clock=SimClock(), policy=CostDriven(),
                     max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan)
    assert ex._watchdog is None    # SimClock: post-hoc accounting instead
    out = ex.collect(iter(_rid_batches(4, per=4)))
    assert _multiset(out) == Counter(range(4))
    assert ex.makespan == pytest.approx(5.0 + 0.04, abs=1e-9)
    f = ex.stats_snapshot()["_faults"]["P"]
    assert f["deadline_hits"] == 1
    assert f["failures"] == 0 and f["successes"] == 1


# ------------------------------------------------------------------ #
# Poison batches, quarantine, degraded routing
# ------------------------------------------------------------------ #
def test_poison_batch_passthrough_after_max_attempts():
    def fn(d):
        if np.isin(13, d["rid"]):
            raise ValueError("poison row")
        return d["rid"] % 2 == 0

    udf = UDF("pz", fn=fn, columns=("rid",), bucket=False)
    p = Predicate("pz", udf, compare=lambda o: o.astype(bool))
    cfg = FaultConfig(mode="retry", max_attempts=2, backoff_base_s=0.0,
                      jitter=0.0, quarantine_after=10)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg)
    out = ex.collect(iter(_rid_batches(20, per=4)))
    # evens survive the filter; the poison batch (rids 12..15) completes
    # with the conservative pass-through verdict: ALL its rows kept
    expected = Counter(
        i for i in range(20) if i % 2 == 0 or i in (12, 13, 14, 15)
    )
    assert _multiset(out) == expected
    poisoned = [b for b in out if "pz" in b.passthrough]
    assert len(poisoned) == 1
    assert set(map(int, poisoned[0].row_ids)) == {12, 13, 14, 15}
    f = ex.stats_snapshot()["_faults"]["pz"]
    assert f["quarantined_batches"] == 1 and f["quarantined_rows"] == 4
    assert f["failures"] == 2 and f["retries"] == 1
    assert not f["quarantined"]            # batch poisoned, predicate fine
    assert f["error_rate"] > 0.0
    assert "poison row" in f["last_error"]


def test_quarantined_predicate_skipped_and_terminates():
    a = planted_predicate("a", range(100), cost_per_row=0.01)
    b = planted_predicate("b", range(0, 100, 2), cost_per_row=0.01)
    plan = FaultPlan().fail("a", probability=1.0)
    cfg = FaultConfig(mode="retry", max_attempts=2, quarantine_after=4,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([a, b], clock=SimClock(), policy=CostDriven(),
                     max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan)
    out = _collect_with_timeout(ex, iter(_rid_batches(24, per=4)))
    # "a" never produces a verdict -> conservative pass-through; "b" still
    # filters: exact multiset = b's survivors, every batch flagged for "a"
    assert _multiset(out) == Counter(range(0, 24, 2))
    assert all("a" in bt.passthrough for bt in out)
    f = ex.stats_snapshot()["_faults"]["a"]
    assert f["quarantined"]
    assert f["quarantined_batches"] >= 2   # the two that tripped quarantine
    assert f["skipped_routes"] > 0         # later batches skipped at routing
    assert ex.stats_snapshot()["_faults"]["b"]["failures"] == 0


def test_warmup_with_always_failing_predicate_terminates():
    """Warmup dispatches ONE batch per unmeasured predicate; a predicate
    that always fails never measures — without the failed-predicate
    exemption in the warmup gate every other batch circulates forever."""
    a = planted_predicate("a", range(100), cost_per_row=0.01)
    b = planted_predicate("b", range(0, 100, 2), cost_per_row=0.01)
    plan = FaultPlan().fail("a", probability=1.0)
    cfg = FaultConfig(mode="retry", max_attempts=2, quarantine_after=4,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([a, b], clock=SimClock(), policy=CostDriven(),
                     max_workers=1, warmup=True, on_fault=cfg,
                     fault_plan=plan)
    out = _collect_with_timeout(ex, iter(_rid_batches(24, per=4)))
    assert _multiset(out) == Counter(range(0, 24, 2))
    assert all("a" in bt.passthrough for bt in out)
    assert ex.stats_snapshot()["_faults"]["a"]["quarantined"]


def test_degrade_switches_to_fallback():
    calls = {"primary": 0, "fallback": 0}

    def primary(d):
        calls["primary"] += 1
        raise RuntimeError("compiled path broken")

    def fallback(d):
        calls["fallback"] += 1
        return d["x"] >= 0

    p = _pred("a", primary, fallback=fallback)
    cfg = FaultConfig(mode="degrade", max_attempts=4, degrade_after=2,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg)
    out = ex.collect(iter(_batches(10)))
    assert _multiset(out) == Counter(range(10))
    assert p.udf.degraded
    assert calls["primary"] == 2           # degrade_after consecutive fails
    assert calls["fallback"] >= 1
    f = ex.stats_snapshot()["_faults"]["a"]
    assert f["degraded"] and f["failures"] == 2 and f["successes"] == 1
    assert all("a" not in bt.passthrough for bt in out)


def test_degrade_escapes_compiled_only_injection():
    """A compiled_only injected fault models a bug in the compiled
    executable: once the UDF degrades to its reference path the spec
    stops firing and evaluation recovers with correct results."""
    p = _pred("a", lambda d: d["x"] >= 0, fallback=lambda d: d["x"] >= 0)
    plan = FaultPlan().fail("a", probability=1.0)   # compiled_only default
    cfg = FaultConfig(mode="degrade", max_attempts=6, degrade_after=2,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan)
    out = ex.collect(iter(_batches(30)))
    assert _multiset(out) == Counter(range(30))
    assert p.udf.degraded
    assert ex.stats_snapshot()["_faults"]["a"]["degraded"]
    assert all("a" not in bt.passthrough for bt in out)


def test_corrupt_output_detected_retried_and_not_cached():
    p = _pred("a", lambda d: d["x"] >= 0)
    plan = FaultPlan().corrupt("a", attempts=(2,))
    cfg = FaultConfig(mode="retry", max_attempts=3, backoff_base_s=0.0,
                      jitter=0.0)
    cache = ReuseCache()
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan, cache=cache)
    out = ex.collect(iter(_batches(20)))    # 2 batches; attempt 2 corrupt
    assert _multiset(out) == Counter(range(20))
    f = ex.stats_snapshot()["_faults"]["a"]
    assert f["failures"] == 1 and f["retries"] == 1
    assert "CorruptOutputError" in f["last_error"]
    # validation precedes caching: the complex128 result never entered
    hits, vals = cache.probe_batch("a_udf", np.arange(10, 20))
    assert hits.all()
    assert all(np.asarray(v).dtype != np.complex128 for v in vals)
    assert all(not b.passthrough for b in out)


# ------------------------------------------------------------------ #
# Failure-aware ranking
# ------------------------------------------------------------------ #
def test_rank_penalty_identity_until_first_failure():
    led = FaultLedger(["a"])
    assert led.rank_penalty("a") == 1.0
    led.note_success("a")
    assert led.rank_penalty("a") == 1.0 and not led.dirty
    led.note_failure("a")
    assert led.dirty and led.rank_penalty("a") > 1.0
    led.note_success("a")                   # recovery decays the EMA...
    r1 = led.rank_penalty("a")
    led.note_success("a")
    assert led.rank_penalty("a") < r1       # ...monotonically under success


def test_failure_penalty_reorders_cost_ranking():
    board = StatsBoard(["a", "b"])
    board["a"].record_eval(10, 5, 0.1)      # 0.01/row: cheap, ranks first
    board["b"].record_eval(10, 5, 0.4)      # 0.04/row
    batch = _batches(10)[0]

    class _P:
        def __init__(self, name):
            self.name = name

    pa, pb = _P("a"), _P("b")
    assert [p.name for p in
            CostDriven().rank(batch, [pa, pb], board, None)] == ["a", "b"]
    board.faults = FaultLedger(["a", "b"])
    # clean ledger: penalty is exactly 1.0, ranking bit-identical
    assert [p.name for p in
            CostDriven().rank(batch, [pa, pb], board, None)] == ["a", "b"]
    board.faults.note_failure("a")          # error-rate EMA -> 1.0, x5 key
    assert [p.name for p in
            CostDriven().rank(batch, [pa, pb], board, None)] == ["b", "a"]


def test_fail_fast_modes_share_one_pinned_timeline():
    """Default, explicit fail_fast, and retry-with-no-faults must produce
    the identical deterministic timeline and statistics."""
    def run(**kw):
        p = planted_predicate("P", range(0, 64, 2), cost_per_row=0.01)
        ex = AQPExecutor([p], clock=SimClock(), policy=CostDriven(),
                         max_workers=1, warmup=False, **kw)
        out = ex.collect(iter(_rid_batches(32, per=4)))
        snap = ex.stats_snapshot()
        return (ex.makespan, sorted(_multiset(out).items()), snap["P"])

    base = run()
    assert run(on_fault="fail_fast") == base
    assert run(on_fault="retry") == base    # no faults -> byte-identical
    assert base[0] == pytest.approx(8 * 4 * 0.01, abs=1e-9)


# ------------------------------------------------------------------ #
# LaunchWatchdog
# ------------------------------------------------------------------ #
def test_watchdog_scan_flags_overdue_launch_once():
    hits = []
    wd = LaunchWatchdog(0.5, lambda name, el: hits.append((name, el)))
    tok = wd.begin("k")
    now = time.monotonic()
    assert wd.scan(now=now + 1.0) == 1
    assert wd.scan(now=now + 2.0) == 0      # flagged exactly once
    assert hits and hits[0][0] == "k" and hits[0][1] > 0.5
    wd.end(tok)
    assert wd.inflight() == 0
    assert wd.began == 1 and wd.flagged == 1


def test_watchdog_callback_exception_swallowed():
    def boom(name, elapsed):
        raise RuntimeError("observer crashed")

    wd = LaunchWatchdog(0.1, boom)
    tok = wd.begin("k")
    assert wd.scan(now=time.monotonic() + 1.0) == 1   # no raise
    wd.end(tok)


def test_watchdog_thread_lifecycle():
    wd = LaunchWatchdog(10.0, lambda n, e: None, interval_s=0.01)
    wd.start()
    assert any(t.name == "fault-watchdog" and t.is_alive()
               for t in threading.enumerate())
    wd.stop()
    assert not any(t.name == "fault-watchdog" and t.is_alive()
                   for t in threading.enumerate())


def test_launch_watchdog_seam_brackets_kernel_launches(rng):
    import jax.numpy as jnp

    from repro.kernels import launch, ops

    wd = LaunchWatchdog(30.0, lambda n, e: None)
    prev = launch.set_launch_watchdog(wd)
    try:
        crops = jnp.asarray(rng.uniform(0, 255, (4, 32, 16, 3)), jnp.float32)
        ops.hsv_color_classify(crops, impl="pallas", block_rows=16)
    finally:
        launch.set_launch_watchdog(prev)
    assert wd.began >= 1                   # the launch was bracketed
    assert wd.inflight() == 0              # ... and unbracketed on return
    assert launch.current_launch_watchdog() is prev


def test_executor_starts_and_stops_watchdog_wall_clock():
    p = _pred("a", lambda d: d["x"] >= 0)
    cfg = FaultConfig(mode="retry", launch_deadline_s=5.0)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg)
    assert ex._watchdog is not None
    out = ex.collect(iter(_batches(10)))
    assert _multiset(out) == Counter(range(10))
    assert ex._watchdog.began >= 1         # worker evaluations bracketed
    # shutdown (run()'s finally) joined the scan thread
    assert ex._watchdog._thread is None
    assert not any(t.name == "fault-watchdog" and t.is_alive()
                   for t in threading.enumerate())


# ------------------------------------------------------------------ #
# Teardown / termination-barrier regressions
# ------------------------------------------------------------------ #
def test_tracker_decremented_on_worker_error():
    def boom(d):
        raise ValueError("kaboom")

    ex = AQPExecutor([_pred("a", boom)], max_workers=1, warmup=False)
    with pytest.raises(RuntimeError, match="predicate worker failed"):
        _collect_with_timeout(ex, iter(_batches(10)))
    assert ex._tracker.value() == 0        # dropped batch was untracked


def test_policy_error_in_shard_fails_promptly_not_hang():
    """Pre-fix: a shard that died routing a batch left the in-flight count
    wedged and sibling shards polled forever."""
    class Boom(CostDriven):
        def __init__(self):
            self.calls = 0

        def rank(self, batch, preds, stats, cache):
            self.calls += 1
            if self.calls >= 3:
                raise RuntimeError("policy boom")
            return super().rank(batch, preds, stats, cache)

    pa = _pred("a", lambda d: d["x"] >= 0)
    pb = _pred("b", lambda d: d["x"] >= 0)
    ex = AQPExecutor([pa, pb], policy=Boom(), shards=2, warmup=False,
                     max_workers=1)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="policy boom"):
        _collect_with_timeout(ex, iter(_batches(60)), timeout=20.0)
    assert time.monotonic() - t0 < 15.0    # prompt abort, not a poll-out


def test_eddy_submit_guard_raises_on_falsy_return():
    from repro.core.eddy import EddyShard

    class FakeLam:
        pred = types.SimpleNamespace(name="p")

        def submit(self, b):
            return False                   # contract violation

    class FakeBatch:
        bid = 7

    with pytest.raises(RuntimeError, match="rejected batch"):
        EddyShard._submit(FakeLam(), FakeBatch())


class _SpyStore:
    def __init__(self):
        self.recorded = 0
        self.flushed = 0

    def warm_start(self, board, preds):
        return {}

    def record_board(self, board, preds, seeded=None):
        self.recorded += 1

    def flush(self):
        self.flushed += 1


def test_context_manager_teardown_on_error():
    from repro.kernels import launch as kernel_launch

    def boom(d):
        raise ValueError("kaboom")

    store = _SpyStore()
    with AQPExecutor([_pred("a", boom)], max_workers=1, warmup=False,
                     stats_store=store) as ex:
        with pytest.raises(RuntimeError, match="predicate worker failed"):
            _collect_with_timeout(ex, iter(_batches(10)))
    # the raise went through shutdown(): stats recorded, hook deregistered
    assert store.recorded == 1 and store.flushed == 1
    assert ex._launch_token not in kernel_launch._TOKEN_HOOKS


def test_context_manager_closes_abandoned_run():
    p = _pred("a", lambda d: d["x"] >= 0)
    with AQPExecutor([p], max_workers=1, warmup=False) as ex:
        it = ex.run(iter(_batches(40)))
        next(it)                           # abandon the generator mid-run
    assert ex._kernel_hook is None         # __exit__ ran shutdown()
    del it


def test_faults_snapshot_key_contract():
    p = _pred("a", lambda d: d["x"] >= 0)
    plan = FaultPlan().fail("a", attempts=(1,))
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault="retry",
                     fault_plan=plan)
    ex.collect(iter(_batches(10)))
    f = ex.stats_snapshot()["_faults"]["a"]
    assert set(f) == {
        "failures", "successes", "retries", "consecutive_failures",
        "error_rate", "quarantined", "degraded", "quarantined_batches",
        "quarantined_rows", "deadline_hits", "skipped_routes", "probes",
        "unquarantines", "last_error",
    }
    assert f["failures"] == 1 and f["successes"] == 1


# ------------------------------------------------------------------ #
# Recovery probes + un-quarantine (PR-9 residual)
# ------------------------------------------------------------------ #
def test_probe_state_machine_success_unquarantines():
    """Ledger-level walk of the probe protocol: quarantine -> skips arm a
    probe -> single probe route/claim -> success clears quarantine."""
    led = FaultLedger(["a"], probe_after_skips=2)
    led.note_failure("a", RuntimeError("x"))
    led.note_failure("a", RuntimeError("x"))
    led.set_quarantined("a")
    assert led.is_quarantined("a")
    assert not led.take_probe_route("a")   # probe not armed yet
    led.note_skip("a")
    led.note_skip("a")                     # threshold -> armed
    assert led.take_probe_route("a")       # claimed exactly once
    assert not led.take_probe_route("a")
    assert led.begin_probe("a")            # worker claims the in-flight flag
    assert not led.begin_probe("a")
    assert led.end_probe("a", success=True)
    assert not led.is_quarantined("a")
    s = led.snapshot()["a"]
    assert s["probes"] == 1 and s["unquarantines"] == 1
    assert s["consecutive_failures"] == 0


def test_probe_failure_rearms_skip_window():
    led = FaultLedger(["a"], probe_after_skips=1)
    led.note_failure("a", RuntimeError("x"))
    led.set_quarantined("a")
    led.note_skip("a")
    assert led.take_probe_route("a") and led.begin_probe("a")
    assert not led.end_probe("a", success=False)
    assert led.is_quarantined("a")         # still out
    assert not led.take_probe_route("a")   # window re-armed: needs new skips
    led.note_skip("a")
    assert led.take_probe_route("a")       # next window arms another probe
    assert led.snapshot()["a"]["probes"] == 2
    assert led.snapshot()["a"]["unquarantines"] == 0


def test_probe_unquarantines_recovered_predicate_end_to_end():
    """A predicate that fails its first two launches gets quarantined,
    skipped batches arm a probe, the probe SUCCEEDS, and routing resumes
    real evaluation — later batches are filtered, not passed through."""
    def fn(d):
        return d["rid"] % 2 == 0

    udf = UDF("pr", fn=fn, columns=("rid",), bucket=False)
    p = Predicate("pr", udf, compare=lambda o: o.astype(bool))
    plan = FaultPlan().fail("pr", attempts=(1, 2))
    cfg = FaultConfig(mode="retry", max_attempts=1, quarantine_after=2,
                      backoff_base_s=0.0, jitter=0.0, probe_after_skips=2)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan)
    out = _collect_with_timeout(ex, iter(_rid_batches(28, per=4)))
    # which batches end up flagged depends on pipeline interleaving (the
    # failed batch recirculates and may itself become the probe), so
    # assert the invariants: flagged batches keep ALL their rows, clean
    # batches are REALLY filtered, every even row survives somewhere,
    # and the probe un-quarantined the predicate.
    flagged = [b for b in out if "pr" in b.passthrough]
    clean = [b for b in out if "pr" not in b.passthrough]
    assert clean, "no batch was evaluated after recovery"
    assert all(int(r) % 2 == 0 for b in clean for r in b.row_ids)
    ms = _multiset(out)
    assert all(ms[i] == 1 for i in range(0, 28, 2))    # evens all survive
    odd_kept = {i for i in range(1, 28, 2) if ms[i]}
    assert odd_kept == {int(r) for b in flagged
                        for r in b.row_ids if int(r) % 2}
    f = ex.stats_snapshot()["_faults"]["pr"]
    assert f["probes"] == 1 and f["unquarantines"] == 1
    assert not f["quarantined"]
    assert f["skipped_routes"] >= 2


def test_probe_off_by_default_preserves_quarantine_behavior():
    led = FaultLedger(["a"])
    led.note_failure("a", RuntimeError("x"))
    led.set_quarantined("a")
    for _ in range(50):
        led.note_skip("a")
        assert not led.take_probe_route("a")
    assert led.is_quarantined("a")
    with pytest.raises(ValueError, match="probe_after_skips"):
        FaultConfig(probe_after_skips=0)


# ------------------------------------------------------------------ #
# Re-verification queue (reverify=; PR-9 residual)
# ------------------------------------------------------------------ #
def test_reverify_queue_drains_after_recovery():
    from repro.core import ReverifyQueue

    def fn(d):
        return d["rid"] % 2 == 0

    udf = UDF("rv", fn=fn, columns=("rid",), bucket=False)
    p = Predicate("rv", udf, compare=lambda o: o.astype(bool))
    led = FaultLedger(["rv"])
    rq = ReverifyQueue([p], led)
    flagged = _rid_batches(4, per=4)[0].mark_passthrough("rv")
    assert rq.offer(flagged) is None       # intercepted, held
    assert rq.pending() == 1
    assert rq.drain() == []                # no successes yet -> not recovered
    led.note_success("rv")
    out = rq.drain()
    assert len(out) == 1 and not out[0].passthrough
    assert _multiset(out) == Counter([0, 2])   # re-verified for real
    snap = rq.snapshot()
    assert snap["intercepted"] == 1 and snap["reverified_batches"] == 1
    assert snap["reverified_rows"] == 4 and snap["dropped_rows"] == 2
    assert snap["pending"] == 0
    # clean batches pass straight through
    clean = _rid_batches(4, per=4)[0]
    assert rq.offer(clean) is clean


def test_reverify_queue_forced_release_keeps_flags():
    from repro.core import ReverifyQueue

    def fn(d):
        raise AssertionError("must not re-evaluate an unrecovered predicate")

    udf = UDF("rv", fn=fn, columns=("rid",), bucket=False)
    p = Predicate("rv", udf, compare=lambda o: o.astype(bool))
    led = FaultLedger(["rv"])
    led.note_failure("rv", RuntimeError("x"))
    led.set_quarantined("rv")                      # quarantined, 0 successes
    rq = ReverifyQueue([p], led)
    flagged = _rid_batches(4, per=4)[0].mark_passthrough("rv")
    assert rq.offer(flagged) is None
    out = rq.drain(force=True)                     # shutdown path
    assert len(out) == 1 and "rv" in out[0].passthrough
    assert _multiset(out) == Counter(range(4))     # conservative: rows kept
    assert rq.snapshot()["released_flagged"] == 1


def test_executor_reverify_repairs_passthrough_batches():
    """End-to-end ``reverify=True``: the batch that completed as a
    pass-through while 'rv' was failing is re-verified once the ledger
    recovers — the final output has NO flagged rows and the exact
    fully-filtered multiset."""
    def fn(d):
        return d["rid"] % 2 == 0

    udf = UDF("rv", fn=fn, columns=("rid",), bucket=False)
    p = Predicate("rv", udf, compare=lambda o: o.astype(bool))
    plan = FaultPlan().fail("rv", attempts=(1,))
    cfg = FaultConfig(mode="retry", max_attempts=1, quarantine_after=100,
                      backoff_base_s=0.0, jitter=0.0)
    ex = AQPExecutor([p], max_workers=1, warmup=False, on_fault=cfg,
                     fault_plan=plan, reverify=True)
    out = _collect_with_timeout(ex, iter(_rid_batches(20, per=4)))
    assert not any(b.passthrough for b in out)
    assert _multiset(out) == Counter(range(0, 20, 2))
    svc = ex.stats_snapshot()["_service"]
    assert svc["reverify"]["reverified_batches"] == 1
    assert svc["reverify"]["intercepted"] == 1
