"""Correctness of the §Perf beyond-paper variants: optimizations must not
change results (beyond the documented precision deltas)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import model_api

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
B, S = 2, 32


def test_fp8_cache_decode_close_to_bf16():
    cfg = get_config("llama3-8b").reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size

    def run(c):
        cache, _ = api.prefill(c, params, {"tokens": toks}, pad_cache_to=S + 4)
        _, logits = api.decode_step(c, params, cache, {"token": toks[:, -1]})
        return np.asarray(logits, np.float32), cache

    ref_logits, _ = run(cfg)
    fp8_logits, fp8_cache = run(dataclasses.replace(cfg, cache_dtype="float8_e4m3fn"))
    assert fp8_cache["k"].dtype == jnp.float8_e4m3fn
    # fp8 storage: small logits drift only
    np.testing.assert_allclose(fp8_logits, ref_logits, rtol=0.2, atol=0.5)
    # ranking preserved for the top token (greedy decode unchanged)
    assert (fp8_logits.argmax(-1) == ref_logits.argmax(-1)).mean() >= 0.5


@pytest.mark.slow
def test_moe_ep2d_decode_matches_baseline_subprocess():
    """Resident-expert 2D EP on a 4-device (2x2) mesh == single-device ref."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import model_api
from repro.models.layers import ShardCtx
from repro.distributed.sharding import SERVE_RULES, named_sharding

cfg = get_config("arctic-480b").reduce_for_smoke()  # 4 experts, dense residual
api = model_api(cfg)
params = api.init_params(cfg, jax.random.key(0))
B, S = 4, 16
toks = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
cache, _ = api.prefill(cfg, params, {"tokens": toks}, pad_cache_to=S + 4)
_, ref = api.decode_step(cfg, params, cache, {"token": toks[:, -1]})

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ShardCtx(mesh, SERVE_RULES)
cfg2 = dataclasses.replace(cfg, moe_serve_ep2d=True)  # E=4 % data=2 == 0
pcache = {
    "k": jax.device_put(cache["k"], named_sharding(cache["k"].shape,
         "layers batch cache_seq kv_heads .", SERVE_RULES, mesh)),
    "v": jax.device_put(cache["v"], named_sharding(cache["v"].shape,
         "layers batch cache_seq kv_heads .", SERVE_RULES, mesh)),
    "lengths": jax.device_put(cache["lengths"],
         named_sharding(cache["lengths"].shape, "batch", SERVE_RULES, mesh)),
}
_, sharded = jax.jit(lambda p, c, b: api.decode_step(cfg2, p, c, b, ctx))(
    params, pcache, {"token": toks[:, -1]})
np.testing.assert_allclose(np.asarray(ref, np.float32),
                           np.asarray(sharded, np.float32),
                           rtol=5e-3, atol=5e-3)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]


@pytest.mark.slow
def test_moe_train_sharded_matches_single_subprocess():
    """The MoE shard_map train path (EP) == single-device loss on 4 devices."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import model_api
from repro.models.layers import ShardCtx
from repro.distributed.sharding import TRAIN_RULES

cfg = get_config("grok-1-314b").reduce_for_smoke()  # 4 experts (reduced)
api = model_api(cfg)
params = api.init_params(cfg, jax.random.key(0))
B, S = 4, 16
batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size,
         "labels": jnp.ones((B, S), jnp.int32)}
ref, _aux = api.loss_fn(cfg, params, batch)

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ShardCtx(mesh, TRAIN_RULES)
sharded, _ = jax.jit(lambda p, b: api.loss_fn(cfg, p, b, ctx))(params, batch)
np.testing.assert_allclose(float(ref), float(sharded), rtol=2e-3, atol=1e-4)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]


@pytest.mark.slow
def test_seq_parallel_loss_matches_subprocess():
    """seq_parallel=True must not change the training loss (4-device mesh)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models.registry import model_api
from repro.models.layers import ShardCtx
from repro.distributed.sharding import TRAIN_RULES

cfg = get_config("llama3-8b").reduce_for_smoke()
api = model_api(cfg)
params = api.init_params(cfg, jax.random.key(0))
B, S = 4, 32
batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size,
         "labels": jnp.ones((B, S), jnp.int32)}
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ctx = ShardCtx(mesh, TRAIN_RULES)
base, _ = jax.jit(lambda p, b: api.loss_fn(cfg, p, b, ctx))(params, batch)
cfg_sp = dataclasses.replace(cfg, seq_parallel=True)
sp, _ = jax.jit(lambda p, b: api.loss_fn(cfg_sp, p, b, ctx))(params, batch)
np.testing.assert_allclose(float(base), float(sp), rtol=1e-4, atol=1e-5)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
