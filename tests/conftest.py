import os
import sys
import threading
import time

# tests see the REAL device count (1 CPU device) — the 512-device flag is
# set ONLY inside launch/dryrun.py (and subprocess tests that exec it).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# --------------------------------------------------------------------------- #
# Leaked-thread guard: retired worker leases (and any other background
# machinery) must not leave live NON-DAEMON threads behind — a forgotten
# join would hang interpreter exit. The session FAILS if the live
# non-daemon thread count grew between session start and finish.
#
# Routing-core threads (eddy-shard-*/eddy-pull) are daemons, so the
# non-daemon count misses them: a shard that never saw the termination
# barrier would linger silently. They get their own check — every shard
# set must have wound down by session end (with a short grace period:
# shards notice queue close/quiescence within SHARD_GET_TIMEOUT_S).
# Launch-watchdog scan threads (core/faults.LaunchWatchdog, name
# "fault-watchdog") are daemons too and must be stop()ped by executor
# shutdown — a lingering one means a teardown path skipped it.
# QueryService threads (launch/serve.py: "svc-dispatch" dispatcher and
# "svc-query-*" runners) are daemons joined by ``close()`` — one alive at
# session end means a service was never closed.
# --------------------------------------------------------------------------- #
_GUARDED_DAEMON_PREFIXES = ("eddy-shard-", "eddy-pull", "fault-watchdog", "svc-")


def _live_nondaemon_threads():
    return [t for t in threading.enumerate() if t.is_alive() and not t.daemon]


def _live_routing_threads():
    return [
        t for t in threading.enumerate()
        if t.is_alive() and t.name.startswith(_GUARDED_DAEMON_PREFIXES)
    ]


def pytest_sessionstart(session):
    session.config._nondaemon_baseline = len(_live_nondaemon_threads())


def pytest_sessionfinish(session, exitstatus):
    baseline = getattr(session.config, "_nondaemon_baseline", None)
    if baseline is None:
        return
    leaked = _live_nondaemon_threads()
    if len(leaked) > baseline:
        names = sorted(t.name for t in leaked)
        sys.stderr.write(
            "\nLEAKED-THREAD GUARD: live non-daemon thread count grew "
            f"from {baseline} to {len(leaked)} across the test session: "
            f"{names}\n(a retired worker lease or thread pool was not "
            "joined/shut down)\n"
        )
        session.exitstatus = 3
    routing = _live_routing_threads()
    if routing:
        # grace: shards poll for global quiescence at SHARD_GET_TIMEOUT_S
        deadline = time.monotonic() + 2.0
        while routing and time.monotonic() < deadline:
            time.sleep(0.05)
            routing = _live_routing_threads()
    if routing:
        names = sorted(t.name for t in routing)
        sys.stderr.write(
            "\nLEAKED-THREAD GUARD: routing shard/pull threads still "
            f"alive at session end: {names}\n(a shard set missed its "
            "termination barrier — pull done + in-flight zero — or an "
            "executor was never shut down)\n"
        )
        session.exitstatus = 3
