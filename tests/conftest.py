import os
import sys

# tests see the REAL device count (1 CPU device) — the 512-device flag is
# set ONLY inside launch/dryrun.py (and subprocess tests that exec it).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
