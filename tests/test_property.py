"""Property-based tests (hypothesis) for the system's invariants.

The headline invariant is the paper's no-accuracy-tradeoff claim: for ANY
predicate costs/selectivities/policies/batch sizes, the AQP result set
EQUALS naive conjunctive evaluation.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (
    AQPExecutor, CostDriven, DataAware, HydroPolicy, Predicate, ReuseAware,
    ReuseCache, RoundRobin, ScoreDriven, SelectivityDriven, SimClock, UDF,
    make_batch,
)
from repro.core.stats import PredicateStats
from repro.core.udf import bucket_rows
from repro.core.queues import CentralQueue

POLICIES = [CostDriven, ScoreDriven, SelectivityDriven, HydroPolicy, ReuseAware]

slow = settings(
    max_examples=20, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def aqp_case(draw):
    n_rows = draw(st.integers(10, 80))
    n_preds = draw(st.integers(1, 4))
    per = draw(st.sampled_from([3, 7, 10, 16]))
    thresholds = [draw(st.floats(-2.0, 2.0)) for _ in range(n_preds)]
    costs = [draw(st.floats(1e-4, 5e-3)) for _ in range(n_preds)]
    policy = draw(st.sampled_from(POLICIES))
    lam_policy = draw(st.sampled_from([RoundRobin, DataAware]))
    seed = draw(st.integers(0, 2**16))
    use_cache = draw(st.booleans())
    use_sim = draw(st.booleans())
    return n_rows, n_preds, per, thresholds, costs, policy, lam_policy, seed, use_cache, use_sim


@given(aqp_case())
@slow
def test_aqp_equals_naive_evaluation(case):
    (n_rows, n_preds, per, thresholds, costs, policy, lam_policy, seed,
     use_cache, use_sim) = case
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n_rows).astype(np.float64)

    preds = []
    for i, (t, c) in enumerate(zip(thresholds, costs)):
        udf = UDF(
            f"u{i}", fn=lambda d, tt=t: d["x"] * 1.0, columns=("x",),
            resource=f"r{i}", cost_model=(lambda rows, cc=c: rows * cc),
        )
        preds.append(Predicate(f"p{i}", udf, compare=lambda o, tt=t: o > tt))

    naive = np.ones(n_rows, bool)
    for t in thresholds:
        naive &= x > t
    expect = set(np.nonzero(naive)[0].tolist())

    batches = [
        make_batch({"x": x[i : i + per]}, np.arange(i, min(i + per, n_rows)))
        for i in range(0, n_rows, per)
    ]
    ex = AQPExecutor(
        preds,
        policy=policy(),
        laminar_policy_factory=lam_policy,
        cache=ReuseCache() if use_cache else None,
        clock=SimClock() if use_sim else None,
        max_workers=3,
    )
    got = {int(i) for b in ex.run(iter(batches)) for i in b.row_ids}
    assert got == expect


@given(
    tickets=st.integers(1, 10_000),
    wins=st.integers(0, 10_000),
)
@settings(max_examples=50, deadline=None)
def test_lottery_selectivity_bounds(tickets, wins):
    wins = min(wins, tickets)
    st_ = PredicateStats("p")
    st_.tickets, st_.wins, st_.batches = tickets, wins, 1
    sel = st_.selectivity()
    assert 0.0 <= sel <= 1.0
    assert abs(sel - (1 - wins / tickets)) < 1e-12
    assert st_.score() >= 0.0


@given(st.integers(0, 1 << 20))
@settings(max_examples=50, deadline=None)
def test_bucket_rows_properties(n):
    b = bucket_rows(max(n, 1))
    assert b >= max(n, 1)
    assert b < 2 * max(n, 1) or b == 1
    assert (b & (b - 1)) == 0  # power of two


@given(n=st.integers(0, 1 << 12), minimum=st.integers(1, 256))
@settings(max_examples=50, deadline=None)
def test_bucket_rows_respects_minimum(n, minimum):
    b = bucket_rows(n, minimum=minimum)
    assert b >= minimum
    assert b >= n or n == 0
    assert b % minimum == 0          # doubling from minimum: minimum * 2^k
    assert b == minimum or b < 2 * max(n, 1)


@given(
    rows=st.integers(1, 60),
    width=st.integers(1, 5),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_udf_padding_invariant(rows, width, seed):
    """For ANY row count (power of two or not), the bucketed UDF output
    equals the unbucketed ``fn`` output on the first ``rows`` rows, and
    ``fn`` only ever sees the bucketed (power-of-two) row count."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, width))
    seen = []

    def fn(d):
        seen.append(len(d["x"]))
        return d["x"].sum(axis=-1) * 2.0   # row-independent, like the kernels

    udf = UDF("u", fn, columns=("x",))
    out = udf({"x": x})
    assert out.shape == (rows,)
    np.testing.assert_allclose(out, x.sum(axis=-1) * 2.0)
    assert seen == [bucket_rows(rows)]


@given(rows=st.integers(1, 60), seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_udf_zero_row_call_matches_probe_dtype(rows, seed):
    """The zero-row path never hands ``fn`` an empty array (it probes with
    one synthesized row, or reuses the cached output spec) and returns an
    empty result with the same dtype as a real evaluation."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, 3)).astype(np.float32)

    def fn(d):
        assert len(d["x"]) > 0
        return (d["x"].sum(-1) > 0).astype(np.int8)

    udf = UDF("u", fn, columns=("x",))
    full = udf({"x": x})
    empty = udf({"x": x[:0]})
    assert empty.shape == (0,)
    assert empty.dtype == full.dtype


@given(
    lam=st.floats(0.05, 1.0),
    cap=st.integers(1, 64),
    items=st.integers(0, 100),
)
@settings(max_examples=30, deadline=None)
def test_watermark_invariant(lam, cap, items):
    q = CentralQueue(capacity=cap, lam=lam)
    limit = max(1, int(cap * lam))
    accepted = 0
    for i in range(items):
        if q.put_pull(i, timeout=0.0):
            accepted += 1
    assert accepted == min(items, limit)
    # worker inserts always succeed
    for i in range(5):
        q.put_worker(i)
    assert len(q) == accepted + 5


@given(
    mask=st.lists(st.booleans(), min_size=1, max_size=64),
)
@settings(max_examples=30, deadline=None)
def test_batch_filter_semantics(mask):
    mask = np.asarray(mask, bool)
    n = len(mask)
    b = make_batch({"x": np.arange(n), "y": np.arange(n) * 2.0}, np.arange(n))
    f = b.filter(mask)
    assert f.rows == int(mask.sum())
    np.testing.assert_array_equal(f.row_ids, np.nonzero(mask)[0])
    np.testing.assert_array_equal(f.data["x"] * 2.0, f.data["y"])
    assert f.bid == b.bid and f.visited == b.visited


@given(
    k=st.sampled_from([1, 2, 4]),
    t=st.integers(1, 64),
    e=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 1000),
)
@settings(max_examples=30, deadline=None)
def test_moe_router_invariants(k, t, e, seed):
    import jax.numpy as jnp

    from repro.kernels import ref

    k = min(k, e)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    w, idx = ref.moe_topk_router(logits, k)
    w, idx = np.asarray(w), np.asarray(idx)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)  # renormalized
    assert (w >= 0).all()
    assert ((0 <= idx) & (idx < e)).all()
    for row in idx:
        assert len(set(row.tolist())) == k  # distinct experts
