"""Unit tests for the version-portable kernel-launch subsystem
(repro.kernels.launch): compat shim resolution under both JAX API
spellings, mesh construction portability, launch timing hooks feeding
StatsBoard, and the no-direct-pallas_call invariant over kernel files.
"""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import launch
from repro.kernels import ops
from repro.core.stats import StatsBoard


# ------------------------------ compat shim ------------------------------- #
class _Params:
    def __init__(self, **kw):
        self.kw = kw


def test_compiler_params_new_spelling():
    mod = types.SimpleNamespace(CompilerParams=_Params)
    assert launch.resolve_compiler_params_cls(mod) is _Params


def test_compiler_params_old_spelling():
    mod = types.SimpleNamespace(TPUCompilerParams=_Params)
    assert launch.resolve_compiler_params_cls(mod) is _Params


def test_compiler_params_new_spelling_wins_over_old():
    class Old(_Params):
        pass

    mod = types.SimpleNamespace(CompilerParams=_Params, TPUCompilerParams=Old)
    assert launch.resolve_compiler_params_cls(mod) is _Params


def test_compiler_params_neither_spelling_raises():
    with pytest.raises(AttributeError):
        launch.resolve_compiler_params_cls(types.SimpleNamespace())


def test_compiler_params_builds_dimension_semantics():
    params = launch.compiler_params(dimension_semantics=["parallel", "arbitrary"])
    assert isinstance(params, launch.CompilerParams)
    assert params.dimension_semantics == ("parallel", "arbitrary")


def test_make_mesh_accepts_axis_types_on_any_version():
    mesh = launch.make_mesh(
        (1,), ("data",), axis_types=(launch.AxisType.Auto,)
    )
    assert mesh.axis_names == ("data",)


def test_forward_compat_polyfills_installed():
    # the polyfills are what let test scripts written against newer JAX
    # (jax.make_mesh(axis_types=...), jax.shard_map(check_vma=...)) run
    # unchanged on the pinned version
    assert hasattr(jax.sharding, "AxisType")
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    assert mesh.devices.size == 1
    assert hasattr(jax, "shard_map")


def test_shard_map_compat_check_vma():
    from jax.sharding import PartitionSpec as P

    mesh = launch.make_mesh((1,), ("data",))
    f = launch.shard_map(
        lambda x: jax.lax.psum(x, "data"),
        mesh=mesh, in_specs=P(None), out_specs=P(None), check_vma=False,
    )
    np.testing.assert_allclose(np.asarray(f(jnp.ones((4,)))), 1.0)


def test_cost_analysis_dict_both_shapes():
    compiled_list = types.SimpleNamespace(cost_analysis=lambda: [{"flops": 2.0}])
    compiled_dict = types.SimpleNamespace(cost_analysis=lambda: {"flops": 3.0})
    compiled_none = types.SimpleNamespace(cost_analysis=lambda: None)
    assert launch.cost_analysis_dict(compiled_list) == {"flops": 2.0}
    assert launch.cost_analysis_dict(compiled_dict) == {"flops": 3.0}
    assert launch.cost_analysis_dict(compiled_none) == {}


# ------------------------------ launch path ------------------------------- #
def test_resolve_impl_auto_matches_backend():
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert launch.resolve_impl("auto") == expect
    assert launch.resolve_impl("pallas") == "pallas"
    assert launch.resolve_impl("xla") == "xla"


def test_launch_hooks_fire_per_launch(rng):
    events = []
    logits = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    with launch.launch_hooks(events.append):
        ops.moe_topk_router(logits, 2, impl="pallas")
    assert len(events) == 1
    ev = events[0]
    assert ev.name == "moe_router"
    assert ev.rows == 32
    assert ev.seconds > 0
    assert ev.backend in ("pallas", "interpret")
    # hook removed on exit: no further events
    ops.moe_topk_router(logits, 2, impl="pallas")
    assert len(events) == 1


def test_stats_board_hook_feeds_record_eval(rng):
    """Kernel launches report cost-per-row like every other predicate (§3.3)."""
    board = StatsBoard([])
    hook = launch.connect_stats_board(board)
    try:
        crops = jnp.asarray(rng.uniform(0, 255, (4, 32, 16, 3)), jnp.float32)
        ops.hsv_color_classify(crops, impl="pallas", block_rows=16)
    finally:
        launch.remove_launch_hook(hook)
    st = board["hsv_color"]
    assert st.measured
    assert st.batches == 1
    assert st.tickets == 4            # rows_in == batch size
    assert st.wins == 0               # compute UDF: no rows dropped
    assert st.cost() > 0              # cost-per-row EMA got a sample


def test_launch_hooks_ignore_jit_tracing(rng):
    """Under jit, no launch happens in the wrapper: recording trace/compile
    time would poison the cost EMA with one inflated sample."""
    events = []
    logits = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    jitted = jax.jit(lambda lg: ops.moe_topk_router(lg, 2, impl="pallas"))
    with launch.launch_hooks(events.append):
        jitted(logits)          # traces + compiles + runs
        jitted(logits)          # cached executable, bypasses the wrapper
    assert events == []


def test_stats_board_hook_inherits_cost_alpha(rng):
    board = StatsBoard([], cost_alpha=0.05)
    hook = launch.connect_stats_board(board)
    try:
        logits = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
        ops.moe_topk_router(logits, 2, impl="pallas")
    finally:
        launch.remove_launch_hook(hook)
    assert board["moe_router"].cost_per_row.alpha == 0.05


def test_no_direct_pallas_launches_in_kernel_files():
    """All kernel launches must go through repro.kernels.launch."""
    kdir = os.path.dirname(ops.__file__)
    offenders = []
    for fname in sorted(os.listdir(kdir)):
        if not fname.endswith(".py") or fname == "launch.py":
            continue
        src = open(os.path.join(kdir, fname)).read()
        if "pl.pallas_call" in src or "CompilerParams" in src:
            offenders.append(fname)
    assert not offenders, offenders
