"""End-to-end behaviour tests: the paper's UC1 query (Listing 2) over
synthetic video, through the full plan -> AQP pipeline, validated against
planted ground truth — the no-accuracy-tradeoff claim, end to end."""
import numpy as np
import pytest

from repro.core import (
    CostDriven, Predicate, Query, ReuseCache, TrivialPredicate, UDF, optimize,
)
from repro.core.policies import EDDY_POLICIES
from repro.data.video import (
    BREEDS, SyntheticVideo, classify_color_batch, crop_to_canonical,
)
from repro.kernels import ops


@pytest.fixture(scope="module")
def video():
    return SyntheticVideo(num_frames=120, seed=3)


def detection_source(video, chunk=32):
    """Scan + ObjectDetector + UNNEST + label='dog' filter + Crop — the
    upstream of the AQP executor in Fig. 3b."""
    dogs = [o for o in video.objects if o.label == "dog"]
    for i in range(0, len(dogs), chunk):
        part = dogs[i : i + chunk]
        crops = np.stack(
            [crop_to_canonical(video.crop(o.frame_id, o.bbox)) for o in part]
        ).astype(np.float32)
        yield {
            "crop": crops,
            "frame_id": np.array([o.frame_id for o in part]),
            "breed_gt": np.array([BREEDS.index(o.breed) for o in part]),
            "_row_id": np.arange(i, i + len(part)),
        }


def make_predicates(video, breed="great dane", color="black"):
    # DogBreedClassifier stand-in: real compute (HSV kernel features) + the
    # planted label column — deterministic, cost-realistic.
    def breed_fn(d):
        _hist, _ = ops.hsv_color_classify(d["crop"], impl="xla")
        return d["breed_gt"]

    breed_udf = UDF("DogBreedClassifier", breed_fn, columns=("crop", "breed_gt"),
                    resource="tpu:0")
    p_breed = Predicate(
        "breed", breed_udf, compare=lambda o: o == BREEDS.index(breed)
    )

    def color_fn(d):
        return np.array([c for c in classify_color_batch(d["crop"])], object)

    color_udf = UDF("DogColorClassifier", color_fn, columns=("crop",),
                    resource="cpu", bucket=False)
    p_color = Predicate("color", color_udf, compare=lambda o: o == color)
    return p_breed, p_color


@pytest.mark.parametrize("policy", sorted(EDDY_POLICIES))
def test_uc1_query_all_policies(video, policy):
    p_breed, p_color = make_predicates(video)
    q = Query(source=detection_source(video), predicates=[p_breed, p_color])
    plan = optimize(q, executor_kwargs=dict(
        policy=EDDY_POLICIES[policy](), max_workers=2,
    ))
    rows = plan.collect_rows()

    dogs = [o for o in video.objects if o.label == "dog"]
    crops = np.stack(
        [crop_to_canonical(video.crop(o.frame_id, o.bbox)) for o in dogs]
    ).astype(np.float32)
    colors = classify_color_batch(crops)
    expect = {
        i for i, (o, c) in enumerate(zip(dogs, colors))
        if o.breed == "great dane" and c == "black"
    }
    assert set(rows["_row_id"].tolist()) == expect
    assert len(expect) > 0  # planted data guarantees matches


def test_uc1_no_reordering_same_answer(video):
    p_breed, p_color = make_predicates(video)
    q = Query(source=detection_source(video), predicates=[p_breed, p_color])
    static = optimize(q, aqp=False).collect_rows()
    q2 = Query(source=detection_source(video), predicates=[p_breed, p_color])
    adaptive = optimize(q2).collect_rows()
    assert set(static["_row_id"].tolist()) == set(adaptive["_row_id"].tolist())


def test_uc2_cache_across_queries(video):
    """Second identical query with a shared cache mostly reuses results.

    Hit rate < 1.0 is expected: rows dropped by the OTHER predicate in pass
    1 were never evaluated here (partial caches — exactly the premise of the
    paper's UC2 reuse-aware routing)."""
    cache = ReuseCache()
    results, stats = [], None
    for i in range(2):
        p_breed, p_color = make_predicates(video)
        q = Query(source=detection_source(video), predicates=[p_breed, p_color])
        # fixed order both passes: this test is about CACHE semantics, so
        # the (wall-clock-dependent) routing order must not vary between
        # passes — reuse-aware ROUTING has its own tests/benchmarks.
        plan = optimize(q, cache=cache, aqp=False,
                        executor_kwargs=dict(max_workers=2))
        results.append(set(plan.collect_rows()["_row_id"].tolist()))
        stats = plan.executor.stats_snapshot()
    assert results[0] == results[1]  # reuse never changes the answer
    assert stats["breed"]["cache_hit_rate"] >= 0.95  # first pred: full reuse
    assert stats["color"]["cache_hit_rate"] >= 0.95  # same order -> same rows


def test_batches_tolerate_mixed_row_id_sources():
    """A source mixing chunks with and without _row_id must still flow:
    real ids pass through, missing ones synthesize position-in-batch."""
    src = [
        {"x": np.arange(4.0), "_row_id": np.arange(100, 104)},
        {"x": np.arange(4.0, 7.0)},  # no _row_id column
    ]
    udf = UDF("u", fn=lambda d: d["x"], columns=("x",))
    p = Predicate("p", udf, compare=lambda o: o >= 0)
    q = Query(source=iter(src), predicates=[p], batch_rows=5)
    plan = optimize(q, executor_kwargs=dict(max_workers=1))
    rows = plan.collect_rows()
    assert sorted(rows["x"].tolist()) == list(np.arange(7.0))


def test_trivial_pushdown():
    src = [{"x": np.arange(10.0), "rating": np.arange(10),
            "_row_id": np.arange(10)}]
    udf = UDF("u", fn=lambda d: d["x"], columns=("x",))
    p = Predicate("p", udf, compare=lambda o: o >= 0)
    q = Query(source=iter(src), predicates=[p],
              trivial=[TrivialPredicate("rating", "<=", 3)], batch_rows=4)
    plan = optimize(q, executor_kwargs=dict(max_workers=1))
    rows = plan.collect_rows()
    assert set(rows["_row_id"].tolist()) == {0, 1, 2, 3}
    assert any("TrivialPushdown" in d for d in plan.description)
