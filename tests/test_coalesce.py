"""Micro-batch coalescing: concat/split_back contract, launch-cost
decomposition, the adaptive planner, and the fused worker path.

The load-bearing property is bit-exactness: fusing k queued batches into
one launch must be invisible to routing semantics — same bids, same
visited sets, same surviving row multiset, same per-row mask outcome as
evaluating each batch alone (core/batch.py's coalescing contract).
"""
import threading
import time
from collections import Counter

import numpy as np
import pytest

from repro.core import (
    AQPExecutor, CoalesceConfig, CoalescePlanner, Predicate, ReuseCache,
    SimClock, UDF, WallClock, concat, make_batch, split_back,
)
from repro.core.batch import BatchSegment
from repro.core.coalesce import COALESCE_QUEUE_CAPACITY
from repro.core.queues import BoundedQueue
from repro.core.stats import (
    LAUNCH_FIT_MIN_SAMPLES, PredicateStats, ShardedPredicateStats, StatsBoard,
)
from repro.core.udf import bucket_rows, pad_rows
from repro.core.worker import (
    _evaluate_with_cache, evaluate_fused, evaluate_predicate,
)


def _pred(name="p", thresh=0.0, fixed=0.0, marginal=0.0, sleep=0.0):
    def fn(cols):
        if sleep:
            time.sleep(sleep)
        return cols["x"]

    cost_model = None
    if fixed or marginal:
        cost_model = lambda r: fixed + marginal * r  # noqa: E731
    udf = UDF(name, fn, columns=("x",), cost_model=cost_model)
    return Predicate(name, udf, compare=lambda o: o > thresh)


def _batches(rng, n, rows_lo=1, rows_hi=12):
    out = []
    rid = 0
    for i in range(n):
        rows = int(rng.integers(rows_lo, rows_hi + 1))
        out.append(make_batch(
            {"x": rng.normal(size=rows)},
            row_ids=np.arange(rid, rid + rows),
            visited=frozenset(rng.choice(["a", "b"], size=2)),
            sim_ready=float(rng.uniform(0, 5)),
        ))
        rid += rows
    return out


# --------------------------- concat / split_back --------------------------- #
class TestConcatSplitBack:
    def test_fused_equals_individual(self, rng):
        """The contract itself: split_back(mask(concat(bs))) is bit-identical
        to evaluating every batch alone (bid, visited, row ids, data)."""
        batches = _batches(rng, 6)
        fused, segs = concat(batches)
        mask = fused.data["x"] > 0.0
        outs = split_back(segs, mask, visit="p")
        assert len(outs) == len(batches)
        for b, out in zip(batches, outs):
            solo = b.filter(b.data["x"] > 0.0).mark_visited("p")
            assert out.bid == b.bid == solo.bid
            assert out.visited == solo.visited
            assert out.warmup == solo.warmup
            assert out.created_at == solo.created_at
            np.testing.assert_array_equal(out.row_ids, solo.row_ids)
            np.testing.assert_array_equal(out.data["x"], solo.data["x"])

    def test_row_id_multiset_preserved(self, rng):
        batches = _batches(rng, 5)
        fused, segs = concat(batches)
        mask = np.ones(fused.rows, bool)
        outs = split_back(segs, mask)
        assert Counter(
            int(r) for o in outs for r in o.row_ids
        ) == Counter(int(r) for b in batches for r in b.row_ids)

    def test_fused_metadata(self):
        a = make_batch({"x": np.ones(2)}, row_ids=np.arange(2),
                       visited=frozenset({"a", "b"}), warmup=True,
                       created_at=1.0, sim_ready=3.0)
        b = make_batch({"x": np.ones(3)}, row_ids=np.arange(2, 5),
                       visited=frozenset({"b", "c"}), warmup=False,
                       created_at=0.5, sim_ready=7.0)
        fused, segs = concat([a, b])
        assert fused.rows == 5
        assert fused.visited == frozenset({"b"})   # intersection
        assert fused.warmup is False               # all()
        assert fused.created_at == 0.5             # earliest
        assert fused.sim_ready == 7.0              # last arrival
        assert [(s.start, s.stop) for s in segs] == [(0, 2), (2, 5)]

    def test_single_batch_passthrough(self):
        b = make_batch({"x": np.arange(3.0)})
        fused, segs = concat([b])
        assert fused is b
        assert segs == [BatchSegment(b, 0, 3)]

    def test_schema_mismatch_raises(self):
        a = make_batch({"x": np.ones(2)})
        b = make_batch({"y": np.ones(2)})
        with pytest.raises(ValueError, match="schemas"):
            concat([a, b])

    def test_empty_input_raises(self):
        with pytest.raises(ValueError):
            concat([])

    def test_mask_length_mismatch_raises(self):
        b = make_batch({"x": np.ones(4)})
        _, segs = concat([b])
        with pytest.raises(ValueError, match="mask"):
            split_back(segs, np.ones(3, bool))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - requirements-dev only
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def fuse_case(draw):
        n = draw(st.integers(1, 6))
        rows = [draw(st.integers(1, 9)) for _ in range(n)]
        seed = draw(st.integers(0, 2**16))
        thresh = draw(st.floats(-1.5, 1.5))
        return rows, seed, thresh

    @given(fuse_case())
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_property_fused_mask_outcome(case):
        """For ANY batch sizes/payloads/threshold: fused evaluation + split
        preserves the row-id multiset, visited sets, and every row's
        individual mask outcome."""
        rows, seed, thresh = case
        rng = np.random.default_rng(seed)
        rid = 0
        batches = []
        for r in rows:
            batches.append(make_batch(
                {"x": rng.normal(size=r)}, row_ids=np.arange(rid, rid + r),
                visited=frozenset(
                    v for v in ("a", "b") if rng.integers(2)
                ),
            ))
            rid += r
        fused, segs = concat(batches)
        mask = fused.data["x"] > thresh
        outs = split_back(segs, mask, visit="p")
        solos = [b.filter(b.data["x"] > thresh).mark_visited("p")
                 for b in batches]
        assert Counter(
            int(r) for o in outs for r in o.row_ids
        ) == Counter(int(r) for s in solos for r in s.row_ids)
        for out, solo in zip(outs, solos):
            assert out.visited == solo.visited
            np.testing.assert_array_equal(out.row_ids, solo.row_ids)
            np.testing.assert_array_equal(out.data["x"], solo.data["x"])


# ----------------------- vectorized cache hit/miss merge ------------------- #
class TestCacheMerge:
    def test_interleaved_hits_large_batch(self):
        """Regression for the vectorized scatter merge: ~1k rows with
        interleaved cache hits must reproduce cached values on hit rows and
        computed values on miss rows, including trailing output dims."""
        rows = 1000
        x = np.arange(rows, dtype=np.float64)
        batch = make_batch({"x": x}, row_ids=np.arange(rows))
        # (rows, 3) outputs exercise the trailing-shape scatter
        fn_calls = []

        def fn(cols):
            fn_calls.append(cols["x"].shape[0])
            return np.stack([cols["x"], cols["x"] * 2, cols["x"] * 3], axis=1)

        udf = UDF("v", fn, columns=("x",))
        pred = Predicate("v", udf, compare=lambda o: o[:, 0] >= 0)
        cache = ReuseCache()
        even = np.arange(0, rows, 2)
        # cached values carry a sentinel offset so hits are distinguishable
        # from recomputation in the merged output
        cache.put("v", even, np.stack(
            [even + 0.5, even * 2.0, even * 3.0], axis=1))
        stats = StatsBoard(["v"])
        data = {"x": x}
        outputs, wall, computed, compute_data = _evaluate_with_cache(
            pred, batch, data, cache=cache, stats=stats)
        assert outputs.shape == (rows, 3)
        assert computed == rows // 2
        # only the misses launched (padded to their power-of-two bucket)
        assert fn_calls == [bucket_rows(rows // 2)]
        np.testing.assert_array_equal(outputs[0::2, 0], even + 0.5)  # hits
        odd = np.arange(1, rows, 2)
        np.testing.assert_array_equal(outputs[1::2, 0], odd)         # computed
        np.testing.assert_array_equal(outputs[:, 1], x * 2)
        np.testing.assert_array_equal(compute_data["x"], x[1::2])

    def test_full_hit_no_compute(self):
        x = np.arange(8.0)
        batch = make_batch({"x": x}, row_ids=np.arange(8))
        udf = UDF("v", lambda c: 1 / 0, columns=("x",))  # must never run
        pred = Predicate("v", udf, compare=lambda o: o > 0)
        cache = ReuseCache()
        cache.put("v", np.arange(8), x + 1)
        stats = StatsBoard(["v"])
        outputs, wall, computed, compute_data = _evaluate_with_cache(
            pred, batch, {"x": x}, cache=cache, stats=stats)
        assert computed == 0 and compute_data is None
        np.testing.assert_array_equal(outputs, x + 1)

    def test_full_hit_skips_proxy_rate(self):
        """Proxy-rate regression: a fully cached evaluation has ~zero wall
        time and must NOT feed the proxy->seconds rate (the old code fed
        the full batch's load against the near-zero cached wall)."""
        x = np.arange(8.0)
        udf = UDF("v", lambda c: c["x"], columns=("x",))
        pred = Predicate("v", udf, compare=lambda o: o >= 0)
        cache = ReuseCache()
        cache.put("v", np.arange(8), x)
        stats = StatsBoard(["v"])
        out = evaluate_predicate(
            pred, make_batch({"x": x}, row_ids=np.arange(8)),
            stats=stats, cache=cache, clock=WallClock(),
            worker_id="w", device_group="cpu")
        assert out.rows == 8
        assert stats.proxy_rate.value is None  # untouched
        # a computing evaluation does feed it
        out = evaluate_predicate(
            pred, make_batch({"x": x}, row_ids=np.arange(100, 108)),
            stats=stats, cache=cache, clock=WallClock(),
            worker_id="w", device_group="cpu")
        assert stats.proxy_rate.value is not None

    def test_partial_hit_proxy_uses_compute_only_load(self):
        """With half the rows cached, the recorded load is the MISS half's
        proxy units (default proxy = input size), not the full batch's."""
        rows = 64
        x = np.arange(rows, dtype=float)
        udf = UDF("v", lambda c: c["x"], columns=("x",))
        pred = Predicate("v", udf, compare=lambda o: o >= 0)
        cache = ReuseCache()
        cache.put("v", np.arange(0, rows, 2), x[0::2])
        seen = []
        stats = StatsBoard(["v"])
        stats.note_proxy_rate, orig = (
            lambda units, secs: seen.append(units), stats.note_proxy_rate)
        evaluate_predicate(
            pred, make_batch({"x": x}, row_ids=np.arange(rows)),
            stats=stats, cache=cache, clock=WallClock(),
            worker_id="w", device_group="cpu")
        assert seen == [rows / 2]


# ------------------------------ pad_rows ----------------------------------- #
class TestPadRows:
    def test_no_copy_fast_path(self):
        v = np.arange(8.0)
        assert pad_rows(v, 8) is v

    def test_edge_fill(self):
        v = np.arange(6.0).reshape(3, 2)
        out = pad_rows(v, 5)
        assert out.shape == (5, 2)
        np.testing.assert_array_equal(out[:3], v)
        np.testing.assert_array_equal(out[3], v[0])
        np.testing.assert_array_equal(out[4], v[0])

    def test_shrink_raises(self):
        with pytest.raises(ValueError):
            pad_rows(np.arange(4.0), 2)


# ------------------------------ get_many ----------------------------------- #
class TestGetMany:
    def test_drains_up_to_limit(self):
        q = BoundedQueue(8)
        for i in range(3):
            q.put(i)
        assert q.get_many(2) == [0, 1]
        assert q.get_many(5) == [2]
        assert q.get_many(5) == []
        assert q.get_many(0) == []

    def test_drains_closed_queue(self):
        q = BoundedQueue(8)
        q.put("a")
        q.close()
        assert q.get_many(4) == ["a"]
        assert q.get_many(4) == []

    def test_wakes_blocked_putter(self):
        q = BoundedQueue(1)
        q.put("first")
        done = threading.Event()

        def putter():
            q.put("second", timeout=5.0)
            done.set()

        t = threading.Thread(target=putter)
        t.start()
        try:
            assert q.get_many(1) == ["first"]
            assert done.wait(5.0)
            assert q.get_many(1) == ["second"]
        finally:
            t.join(5.0)


# ----------------------- launch-cost decomposition ------------------------- #
class TestLaunchDecomposition:
    def test_fits_affine_cost(self):
        st_ = PredicateStats("p")
        for rows in (4, 8, 16, 32, 64, 16, 8, 32):
            st_.record_eval(rows, rows, 0.01 + 0.001 * rows)
        fixed, marginal = st_.launch_decomposition()
        assert fixed == pytest.approx(0.01, rel=0.05)
        assert marginal == pytest.approx(0.001, rel=0.05)

    def test_none_below_min_samples(self):
        st_ = PredicateStats("p")
        for rows in (4, 8, 16):
            st_.record_eval(rows, rows, 0.01 + 0.001 * rows)
        assert st_.launch_decomposition(
            min_samples=LAUNCH_FIT_MIN_SAMPLES) is None

    def test_none_without_row_spread(self):
        st_ = PredicateStats("p")
        for _ in range(10):
            st_.record_eval(8, 8, 0.02)
        assert st_.launch_decomposition() is None

    def test_cache_hits_excluded(self):
        st_ = PredicateStats("p")
        st_.record_eval(100, 100, 1e-6, computed_rows=0)  # full cache hit
        assert st_.launches == 0
        st_.record_eval(100, 100, 0.05, computed_rows=50)
        assert st_.launches == 1

    def test_clamps_negative_intercept(self):
        st_ = PredicateStats("p")
        # noisy samples engineered toward a negative intercept
        for rows, secs in ((2, 0.001), (4, 0.005), (8, 0.011), (16, 0.025)):
            st_.record_eval(rows, rows, secs)
        fixed, marginal = st_.launch_decomposition()
        assert fixed >= 0.0 and marginal >= 0.0

    def test_sharded_cross_stripe_variance(self):
        """Each stripe sees ONE batch size (zero within-stripe variance);
        the merged fold must still identify the slope from the spread
        ACROSS stripes."""
        sh = ShardedPredicateStats("p", [PredicateStats("p"),
                                         PredicateStats("p")])
        for _ in range(4):
            sh.stripe(0).record_eval(8, 8, 0.01 + 0.001 * 8)
            sh.stripe(1).record_eval(64, 64, 0.01 + 0.001 * 64)
        assert sh.stripe(0).launch_decomposition() is None  # no spread
        fixed, marginal = sh.launch_decomposition()
        assert fixed == pytest.approx(0.01, rel=1e-6)
        assert marginal == pytest.approx(0.001, rel=1e-6)

    def test_record_fused_eval_accounting(self):
        st_ = PredicateStats("p")
        st_.record_fused_eval([(8, 4, None), (8, 8, None), (4, 0, None)],
                              0.05)
        assert st_.batches == 3        # one per original segment
        assert st_.tickets == 20
        assert st_.wins == 8
        assert st_.launches == 1       # ONE launch sample
        assert st_.fused_launches == 1
        assert st_.fused_batches == 3
        assert st_.coalesced_rows == 20
        assert st_.cost_per_row.value == pytest.approx(0.05 / 20)


# ------------------------------- planner ----------------------------------- #
class TestCoalescePlanner:
    def test_resolve_spellings(self):
        assert CoalesceConfig.resolve(None) is None
        assert CoalesceConfig.resolve(False) is None
        assert CoalesceConfig.resolve(0) is None
        assert CoalesceConfig.resolve("off") is None
        assert CoalesceConfig.resolve("adaptive").mode == "adaptive"
        assert CoalesceConfig.resolve(True).mode == "adaptive"
        assert CoalesceConfig.resolve("fixed").mode == "fixed"
        cfg = CoalesceConfig.resolve(4)
        assert cfg.mode == "fixed" and cfg.k == 4
        assert CoalesceConfig.resolve(cfg) is cfg
        assert CoalesceConfig.resolve(CoalesceConfig(mode="off", k=8)) is None
        with pytest.raises(ValueError):
            CoalesceConfig.resolve("bogus")
        with pytest.raises(ValueError):
            CoalesceConfig(mode="adaptive", k=1)

    def _planner(self, pred, mode="adaptive", **kw):
        return CoalescePlanner(
            pred, PredicateStats(pred.name),
            CoalesceConfig(mode=mode), **kw)

    def test_seed_from_cost_model(self):
        pl = self._planner(_pred(fixed=0.01, marginal=0.001))
        fixed, marginal = pl.estimate()
        assert fixed == pytest.approx(0.01)
        assert marginal == pytest.approx(0.001)
        # target = fixed / (eps * marginal) = 0.01 / (0.25 * 0.001) = 40
        assert pl.target_rows() in (39, 40)  # fp rounding on the division
        plan = pl.plan(first_rows=8)
        assert plan is not None and plan.target_rows in (39, 40)
        assert pl.plan(first_rows=40) is None        # saturated: decline
        assert pl.counters()["declines"] == 1

    def test_declines_without_evidence(self):
        pl = self._planner(_pred())  # no cost model, no samples
        assert pl.estimate() is None
        assert pl.plan(first_rows=1) is None

    def test_declines_zero_overhead(self):
        pl = self._planner(_pred(marginal=0.001))  # fixed == 0
        assert pl.plan(first_rows=1) is None

    def test_pure_fixed_cost_caps_at_max_rows(self):
        pl = self._planner(_pred(fixed=0.01))  # marginal == 0
        assert pl.target_rows() == pl.config.max_rows

    def test_online_fit_overrides_seed(self):
        pred = _pred(fixed=0.01, marginal=0.001)
        entry = PredicateStats(pred.name)
        pl = CoalescePlanner(pred, entry, CoalesceConfig())
        # observed reality: 10x the seeded overhead
        for rows in (4, 8, 16, 32, 64, 8):
            entry.record_eval(rows, rows, 0.1 + 0.001 * rows)
        fixed, _ = pl.estimate()
        assert fixed == pytest.approx(0.1, rel=0.05)

    def test_rejects_unusable_cost_model(self):
        def bad(rows):
            raise ValueError("data-aware: needs the batch")

        udf = UDF("p", lambda c: c["x"], columns=("x",), cost_model=bad)
        pl = self._planner(Predicate("p", udf, compare=lambda o: o > 0))
        assert pl.estimate() is None

    def test_fixed_mode_always_plans(self):
        pl = self._planner(_pred(), mode="fixed")
        plan = pl.plan(first_rows=10_000)
        assert plan is not None and plan.max_batches == pl.config.k

    def test_simclock_forces_zero_wait(self):
        pl = self._planner(_pred(fixed=0.01, marginal=0.001),
                           wall_clock=False)
        assert pl.plan(first_rows=1).max_wait_s == 0.0


# ------------------------- fused evaluation -------------------------------- #
class TestEvaluateFused:
    def test_simclock_single_launch_occupancy(self):
        """A fused launch is ONE occupy_shared: starts at the LAST
        constituent's arrival, costs one fixed term + summed row terms, and
        every split output inherits the fused finish."""
        pred = _pred(fixed=0.01, marginal=0.001)
        clock = SimClock()
        stats = StatsBoard(["p"])
        a = make_batch({"x": np.ones(8)}, row_ids=np.arange(8), sim_ready=0.0)
        b = make_batch({"x": -np.ones(8)}, row_ids=np.arange(8, 16),
                       sim_ready=5.0)
        outs = evaluate_fused(
            pred, [a, b], stats=stats, cache=None, clock=clock,
            worker_id="w", device_group="cpu")
        finish = 5.0 + 0.01 + 0.001 * 16   # one launch term, summed rows
        assert [o.sim_ready for o in outs] == [finish, finish]
        assert [o.rows for o in outs] == [8, 0]
        assert outs[0].bid == a.bid and outs[1].bid == b.bid
        entry = stats["p"]
        assert entry.launches == 1 and entry.fused_launches == 1
        assert entry.tickets == 16 and entry.wins == 8

    def test_fused_with_cache_partial_hits(self):
        pred = _pred()
        cache = ReuseCache()
        cache.put("p", np.array([0, 1]), np.array([1.0, -1.0]))
        stats = StatsBoard(["p"])
        a = make_batch({"x": np.array([9.0, 9.0])}, row_ids=np.arange(2))
        b = make_batch({"x": np.array([3.0, -3.0])}, row_ids=np.arange(2, 4))
        outs = evaluate_fused(
            pred, [a, b], stats=stats, cache=cache,
            clock=WallClock(), worker_id="w", device_group="cpu")
        # rows 0/1 resolve from cache (1.0 pass, -1.0 fail), rows 2/3 compute
        np.testing.assert_array_equal(outs[0].row_ids, [0])
        np.testing.assert_array_equal(outs[1].row_ids, [2])


# --------------------------- end-to-end executor --------------------------- #
class TestExecutorCoalescing:
    def _run(self, coalesce, *, shards=None, n=48, rows=8, seed=7):
        rng = np.random.default_rng(seed)
        preds = [_pred("p1", thresh=-1.0, fixed=0.002, marginal=1e-5,
                       sleep=0.002),
                 _pred("p2", thresh=-0.5, fixed=0.002, marginal=1e-5,
                       sleep=0.002)]
        batches = []
        for i in range(n):
            r = 0 if i % 16 == 15 else rows  # empties exercise rows==0 path
            batches.append(make_batch(
                {"x": rng.normal(size=r)},
                row_ids=np.arange(i * rows, i * rows + r), bid=1000 + i))
        ex = AQPExecutor(preds, coalesce=coalesce, warmup=False,
                         max_workers=1, shards=shards)
        outs = ex.collect(iter(batches))
        expected = Counter(
            int(r)
            for b in batches
            for r in b.row_ids[(b.data["x"] > -1.0) & (b.data["x"] > -0.5)]
        )
        got = Counter(int(r) for o in outs for r in o.row_ids)
        assert got == expected
        return ex

    def test_threaded_sharded_terminates_with_coalescing(self):
        """In-flight accounting with fused launches splitting into k
        outputs: a 2-shard threaded run with coalescing on must terminate
        (the termination barrier sees one completion per started batch)
        and produce exactly the naive result set."""
        ex = self._run("adaptive", shards=2)
        snap = ex.stats_snapshot()
        fused = sum(snap[p]["fused_launches"] for p in ("p1", "p2"))
        assert fused > 0, "coalescing path was never exercised"
        assert snap["_coalesce"]["mode"] == "adaptive"

    def test_fixed_mode_end_to_end(self):
        ex = self._run(4)
        snap = ex.stats_snapshot()
        assert snap["_coalesce"]["mode"] == "fixed"
        assert sum(snap[p]["fused_launches"] for p in ("p1", "p2")) > 0

    def test_off_by_default_and_no_snapshot_key(self):
        ex = self._run(None, n=16)
        snap = ex.stats_snapshot()
        assert "_coalesce" not in snap
        assert sum(snap[p]["fused_launches"] for p in ("p1", "p2")) == 0

    def test_queue_capacity_defaults(self):
        preds = [_pred("p1")]
        ex = AQPExecutor(preds, coalesce="adaptive")
        try:
            w = ex.laminars["p1"].workers[0]
            assert w.queue.capacity == COALESCE_QUEUE_CAPACITY
        finally:
            ex.shutdown()
        ex = AQPExecutor(preds)
        try:
            assert ex.laminars["p1"].workers[0].queue.capacity == 2
        finally:
            ex.shutdown()
        ex = AQPExecutor(preds, coalesce="adaptive", worker_queue_capacity=3)
        try:
            assert ex.laminars["p1"].workers[0].queue.capacity == 3
        finally:
            ex.shutdown()

    def test_simclock_deterministic_with_coalescing_off(self):
        """Pinned-timeline guard: the SimClock makespan with the default
        (coalescing off) is identical run-to-run — the knob's default
        cannot perturb the deterministic suites."""
        def run():
            preds = [_pred("p1", thresh=-10.0, fixed=0.0, marginal=0.001)]
            batches = [make_batch({"x": np.ones(8) * (i + 1)},
                                  row_ids=np.arange(i * 8, i * 8 + 8),
                                  bid=i)
                       for i in range(10)]
            ex = AQPExecutor(preds, clock=SimClock(), warmup=False,
                             max_workers=1)
            ex.collect(iter(batches))
            return ex.makespan

        m1, m2 = run(), run()
        assert m1 == m2 > 0
