"""Routing-policy tests, including the paper's Fig. 4 worked example."""
import numpy as np
import pytest

from repro.core import (
    AQPExecutor, CostDriven, HydroPolicy, Predicate, ReuseAware, ReuseCache,
    ScoreDriven, SelectivityDriven, SimClock, UDF, make_batch,
)
from repro.core.stats import StatsBoard


def _pred(name, pass_ids, cost_per_row, resource):
    """Predicate passing exactly the rows whose id is in pass_ids."""
    ids = set(pass_ids)
    udf = UDF(
        name + "_udf",
        fn=lambda d: np.asarray([i in ids for i in d["rid"].tolist()]),
        columns=("rid",),
        resource=resource,
        cost_model=lambda rows: rows * cost_per_row,
    )
    return Predicate(name, udf, compare=lambda out: out.astype(bool))


def _seed(stats: StatsBoard, name: str, cost: float, sel: float):
    """Pre-seed statistics (cost per row, selectivity) for policy tests."""
    st = stats[name]
    st.cost_per_row.update(cost)
    st.tickets = 1000
    st.wins = int(1000 * (1 - sel))
    st.batches = 1


def _run(policy, preds, batches, *, seed_stats):
    clk = SimClock()
    ex = AQPExecutor(list(preds), policy=policy, clock=clk,
                     max_workers=1, warmup=False)
    for name, cost, sel in seed_stats:
        _seed(ex.stats, name, cost, sel)
    got = set()
    for b in ex.run(iter(batches)):
        got |= set(b.row_ids.tolist())
    return got, ex.makespan


def fig4_setup():
    """Paper Fig. 4: breed (cost 2, sel 0.1, gpu) vs color (cost 1, sel 0.6, cpu).

    10 single-row batches. Expected rows: the single row passing both."""
    breed_pass = {0}
    color_pass = set(range(6))
    breed = _pred("breed", breed_pass, 2.0, "gpu:0")
    color = _pred("color", color_pass, 1.0, "cpu")
    batches = [
        make_batch({"rid": np.array([i])}, np.array([i])) for i in range(10)
    ]
    seed = [("breed", 2.0, 0.1), ("color", 1.0, 0.6)]
    return breed, color, batches, seed, breed_pass & color_pass


def test_fig4_worked_example():
    breed, color, batches, seed, expect = fig4_setup()

    got_c, t_cost = _run(CostDriven(), [breed, color], batches, seed_stats=seed)
    got_s, t_score = _run(ScoreDriven(), [breed, color], batches, seed_stats=seed)
    got_v, t_sel = _run(SelectivityDriven(), [breed, color], batches, seed_stats=seed)

    assert got_c == got_s == got_v == expect
    # paper timeline: cost-driven ~14 units, score/selectivity-driven ~20
    assert t_cost <= 15.0, t_cost
    assert t_score >= 19.0, t_score
    assert t_sel >= 19.0, t_sel
    assert t_cost < t_score


def test_hydro_policy_switches_on_concurrency():
    """Concurrent resources -> cost order; shared resource -> score order."""
    stats = StatsBoard(["a", "b"])
    _seed(stats, "a", cost=2.0, sel=0.05)  # score 2/0.95=2.1
    _seed(stats, "b", cost=1.0, sel=0.6)   # score 1/0.4 =2.5
    pa = _pred("a", set(), 2.0, "gpu:0")
    pb = _pred("b", set(), 1.0, "cpu")
    batch = make_batch({"rid": np.arange(4)})
    hp = HydroPolicy()
    order = hp.rank(batch, [pa, pb], stats, None)
    assert [p.name for p in order] == ["b", "a"]  # cost-driven (concurrent)

    pa2 = _pred("a", set(), 2.0, "cpu")  # same resource now
    order2 = hp.rank(batch, [pa2, pb], stats, None)
    assert [p.name for p in order2] == ["a", "b"]  # score-driven fallback


def test_cost_driven_never_worse_fig7_grid():
    """Fig. 7 reproduction: cost-driven <= score/selectivity-driven makespan
    across the selectivity grid (A cost 10ms, B cost 20ms)."""
    rng = np.random.default_rng(0)
    worse = []
    for sel_b in (0.1, 0.5, 0.9):
        for sel_a in (0.1, 0.5, 0.9):
            n = 60
            a_pass = set(rng.choice(n, int(n * sel_a), replace=False).tolist())
            b_pass = set(rng.choice(n, int(n * sel_b), replace=False).tolist())
            A = _pred("A", a_pass, 0.010, "cpu")
            B = _pred("B", b_pass, 0.020, "gpu:0")
            batches = [
                make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
                for i in range(0, n, 10)
            ]
            seed = [("A", 0.010, sel_a), ("B", 0.020, sel_b)]
            _, t_cost = _run(CostDriven(), [A, B], batches, seed_stats=seed)
            _, t_score = _run(ScoreDriven(), [A, B], batches, seed_stats=seed)
            _, t_sel = _run(SelectivityDriven(), [A, B], batches, seed_stats=seed)
            if t_cost > min(t_score, t_sel) * 1.02:  # 2% scheduling noise
                worse.append((sel_a, sel_b, t_cost, t_score, t_sel))
    assert not worse, worse


def test_tied_selectivity_rank_is_deterministic():
    """Regression (degenerate statistics): at sel_a == sel_b the rank order
    must be well-defined, not an artifact of estimator noise. The lottery
    estimator drifts by ~1/tickets per recorded batch; ranking on the raw
    float made SelectivityDriven flip order mid-run and (luckily) beat
    CostDriven on the Fig. 7 grid at sel=0.5/0.5."""
    stats = StatsBoard(["A", "B"])
    _seed(stats, "A", cost=0.010, sel=0.5)
    _seed(stats, "B", cost=0.020, sel=0.5)
    A = _pred("A", set(), 0.010, "cpu")
    B = _pred("B", set(), 0.020, "gpu:0")
    batch = make_batch({"rid": np.arange(4)})

    # noise-level drift (well under the rank resolution) must not flip order
    for da, db in [(0, 0), (+3, 0), (0, +3), (-3, +2)]:
        stats["A"].wins = int(1000 * 0.5) + da
        stats["B"].wins = int(1000 * 0.5) + db
        for policy in (CostDriven(), SelectivityDriven(), ScoreDriven()):
            order = [p.name for p in policy.rank(batch, [B, A], stats, None)]
            assert order == ["A", "B"], (policy.name, da, db, order)


def test_cost_driven_matches_selectivity_driven_at_tied_grid_cell():
    """The exact failing Fig. 7 cell: sel_a == sel_b == 0.5. With the
    deterministic tie-break both policies produce the same schedule, so
    cost-driven can no longer lose to selectivity-driven here."""
    rng = np.random.default_rng(7)
    n = 60
    a_pass = set(rng.choice(n, n // 2, replace=False).tolist())
    b_pass = set(rng.choice(n, n // 2, replace=False).tolist())
    A = _pred("A", a_pass, 0.010, "cpu")
    B = _pred("B", b_pass, 0.020, "gpu:0")
    seed = [("A", 0.010, 0.5), ("B", 0.020, 0.5)]

    def batches():
        return [
            make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
            for i in range(0, n, 10)
        ]

    _, t_cost = _run(CostDriven(), [A, B], batches(), seed_stats=seed)
    _, t_sel = _run(SelectivityDriven(), [A, B], batches(), seed_stats=seed)
    assert t_cost <= t_sel * 1.02, (t_cost, t_sel)


def test_reuse_aware_prefers_cached_predicate():
    """UC2: with a full cache for the expensive predicate, reuse-aware
    ranks it FIRST while plain cost-driven keeps it last."""
    cache = ReuseCache()
    stats = StatsBoard(["cheap", "costly"])
    _seed(stats, "cheap", cost=1.0, sel=0.5)
    _seed(stats, "costly", cost=10.0, sel=0.5)
    cheap = _pred("cheap", set(range(100)), 1.0, "cpu")
    costly = _pred("costly", set(range(100)), 10.0, "gpu:0")
    rows = np.arange(10)
    cache.put(costly.udf.name, rows, np.ones(10))
    batch = make_batch({"rid": rows}, rows)

    cost_order = CostDriven().rank(batch, [costly, cheap], stats, cache)
    reuse_order = ReuseAware().rank(batch, [costly, cheap], stats, cache)
    assert [p.name for p in cost_order] == ["cheap", "costly"]
    assert [p.name for p in reuse_order] == ["costly", "cheap"]


def test_reuse_aware_estimated_cost_formula():
    """estimated cost = (1 - hit_rate) * cost (§4.3)."""
    cache = ReuseCache()
    stats = StatsBoard(["p"])
    _seed(stats, "p", cost=4.0, sel=0.5)
    p = _pred("p", set(), 4.0, "cpu")
    rows = np.arange(8)
    cache.put(p.udf.name, rows[:2], np.ones(2))  # hit rate 0.25
    batch = make_batch({"rid": rows}, rows)
    est = ReuseAware().est_cost(batch, p, stats, cache)
    assert est == pytest.approx((1 - 0.25) * 4.0)


def test_content_based_routing_per_bucket_orders():
    """Content-based routing [Bizarro et al.]: per-bucket selectivities
    produce DIFFERENT predicate orders for different content, while global
    stats see both predicates as identical."""
    from repro.core.policies import ContentBased

    stats = StatsBoard(["A", "B"])
    for st in (stats["A"], stats["B"]):
        st.cost_per_row.update(1.0)
        st.batches = 1
    # bucket 0: A drops everything, B passes; bucket 1: reversed.
    stats["A"].record_eval(100, 0, 100.0, bucket=0)
    stats["A"].record_eval(100, 100, 100.0, bucket=1)
    stats["B"].record_eval(100, 100, 100.0, bucket=0)
    stats["B"].record_eval(100, 0, 100.0, bucket=1)
    # globals are now symmetric (sel 0.5 each)
    assert stats["A"].selectivity() == stats["B"].selectivity() == 0.5

    pa = _pred("A", set(), 1.0, "r0")
    pb = _pred("B", set(), 1.0, "r1")
    policy = ContentBased(lambda b: int(b.data["x"][0]))
    b0 = make_batch({"x": np.zeros(4)})
    b1 = make_batch({"x": np.ones(4)})
    assert [p.name for p in policy.rank(b0, [pa, pb], stats, None)] == ["A", "B"]
    assert [p.name for p in policy.rank(b1, [pa, pb], stats, None)] == ["B", "A"]


def test_bucket_selectivity_fallback():
    """Sparse buckets fall back to the global estimate."""
    st = StatsBoard(["p"])["p"]
    st.record_eval(1000, 500, 1.0)            # global sel 0.5
    st.record_eval(5, 0, 0.01, bucket=7)      # only 5 tickets in bucket 7
    # below min_bucket_tickets -> falls back to the GLOBAL estimate
    assert st.selectivity(bucket=7) == pytest.approx(st.selectivity())
    st.record_eval(50, 0, 0.1, bucket=7)      # everything dropped
    assert st.selectivity(bucket=7) < 0.1     # now bucket-specific pass rate
