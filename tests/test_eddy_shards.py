"""Sharded eddy routing core: correctness under N shards, work-stealing,
termination barrier, auto-scaling, and the pinned circulation order."""
import collections
import time

import numpy as np
import pytest

from repro.core import (
    SHARD_AUTO_MAX,
    AQPExecutor,
    CostDriven,
    InFlightTracker,
    Predicate,
    SimClock,
    UDF,
    make_batch,
)
from repro.core.queues import CentralQueue


def _pred(name, fn=None, sleep_s=0.0, resource="cpu"):
    def _fn(d, _fn=fn, _s=sleep_s):
        if _s:
            time.sleep(_s)
        return (_fn or (lambda cols: cols["x"] >= 0))(d)

    udf = UDF(name + "_udf", fn=_fn, columns=("x",), resource=resource,
              bucket=False)
    return Predicate(name, udf, compare=lambda out: out.astype(bool))


def _batches(n, per=8):
    return [
        make_batch({"x": np.arange(i * per, (i + 1) * per, dtype=np.float64)},
                   np.arange(i * per, (i + 1) * per))
        for i in range(n)
    ]


def _row_multiset(out):
    c = collections.Counter()
    for b in out:
        c.update(int(i) for i in b.row_ids)
    return c


# --------------------------------------------------------------------------- #
# InFlightTracker
# --------------------------------------------------------------------------- #
def test_in_flight_tracker_counts():
    t = InFlightTracker()
    assert t.value() == 0
    t.started(); t.started()
    assert t.value() == 2
    t.finished()
    assert t.value() == 1
    t.finished()
    assert t.value() == 0


# --------------------------------------------------------------------------- #
# Sharded runs: same results, stealing active, clean termination
# --------------------------------------------------------------------------- #
def _row_multiset_of_source(n, per=8):
    c = collections.Counter()
    for i in range(n * per):
        c[i] += 1
    return c


@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_run_matches_single_shard_rowid_multiset(shards):
    def build(k):
        preds = [_pred(f"p{i}", sleep_s=0.002) for i in range(4)]
        return AQPExecutor(preds, policy=CostDriven(), max_workers=1,
                           warmup=False, shards=k)

    base = _row_multiset(build(1).collect(_batches(40)))
    ex = build(shards)
    got = _row_multiset(ex.collect(_batches(40)))
    assert got == base  # nothing lost, nothing duplicated
    assert got == _row_multiset_of_source(40)
    assert ex.shards_active == shards


def test_sharded_run_steals_from_siblings():
    preds = [_pred(f"p{i}", sleep_s=0.003) for i in range(4)]
    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=1,
                     warmup=False, shards=4)
    out = ex.collect(_batches(60))
    assert _row_multiset(out) == _row_multiset_of_source(60)
    # uneven drain across 4 stripes over 60 batches: stealing must fire
    assert ex.stats_snapshot()["_routing"]["steals"] > 0


def test_sharded_warmup_measures_all_predicates():
    preds = [_pred(f"p{i}", sleep_s=0.002) for i in range(3)]
    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=1, shards=2)
    out = ex.collect(_batches(30))
    snap = ex.stats_snapshot()
    assert all(snap[f"p{i}"]["batches"] > 0 for i in range(3))
    assert _row_multiset(out) == _row_multiset_of_source(30)


def test_sharded_empty_source_terminates():
    preds = [_pred("a"), _pred("b")]
    ex = AQPExecutor(preds, warmup=False, shards=4)
    t0 = time.monotonic()
    assert ex.collect(iter([])) == []
    assert time.monotonic() - t0 < 5.0  # termination barrier, no hang


def test_sharded_worker_exception_propagates():
    def boom(d):
        raise ValueError("kaboom")

    ex = AQPExecutor([_pred("a", fn=boom)], max_workers=1, warmup=False,
                     shards=2)
    with pytest.raises(RuntimeError, match="predicate worker failed"):
        ex.collect(_batches(6))


# --------------------------------------------------------------------------- #
# Shard-count resolution: explicit, auto, SimClock-deterministic
# --------------------------------------------------------------------------- #
def test_simclock_defaults_to_single_shard():
    clk = SimClock()
    ex = AQPExecutor([_pred("a"), _pred("b")], clock=clk)
    assert ex._max_shards == 1  # deterministic path never auto-scales
    ex.collect(_batches(10))
    assert ex.shards_active == 1


def test_explicit_shards_rejects_zero():
    with pytest.raises(ValueError):
        AQPExecutor([_pred("a")], shards=0)


def test_auto_scale_trips_above_threshold():
    # cheap predicates, threshold ~0: the one-shot growth must trip after
    # SHARD_AUTO_MIN_COMPLETED completions and start the remaining shards
    preds = [_pred(f"p{i}") for i in range(2)]
    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=1, warmup=False,
                     shards=None, shard_auto_threshold=0.001)
    out = ex.collect(_batches(100))
    assert _row_multiset(out) == _row_multiset_of_source(100)
    assert ex.shards_active == SHARD_AUTO_MAX
    assert ex._router.grew_at is not None
    assert ex._router.grew_at >= 64  # SHARD_AUTO_MIN_COMPLETED


def test_auto_scale_stays_single_below_threshold():
    preds = [_pred(f"p{i}") for i in range(2)]
    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=1, warmup=False,
                     shards=None, shard_auto_threshold=1e12)
    out = ex.collect(_batches(80))
    assert _row_multiset(out) == _row_multiset_of_source(80)
    assert ex.shards_active == 1
    assert ex._router.grew_at is None


# --------------------------------------------------------------------------- #
# Circulation order regression: head-pop -> TAIL reinsert, no put_front
# --------------------------------------------------------------------------- #
def test_put_front_is_gone():
    # the dead head-insert path was removed: the warmup circular flow
    # reinserts at the tail via put_worker (see below)
    assert not hasattr(CentralQueue, "put_front")


def test_circular_flow_reinserts_at_tail():
    q = CentralQueue(capacity=8, lam=0.5)
    q.put_pull("b1")
    q.put_pull("b2")
    head = q.get(timeout=0.1)
    assert head == "b1"
    q.put_worker(head)  # circulate: delayed batch goes to the TAIL
    assert q.get(timeout=0.1) == "b2"  # younger batch now ahead of it
    assert q.get(timeout=0.1) == "b1"
