"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step + prefill + decode on CPU; asserts shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import model_api
from repro.optim import AdamW

B, S = 2, 32


def _batch(cfg):
    batch = {
        "tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size,
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.full((B, cfg.num_patches, 1024), 0.1, jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.full((B, cfg.num_frames, cfg.d_model), 0.1, jnp.float32)
    return batch


@pytest.fixture(scope="module")
def states():
    return {}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch, states):
    cfg = get_config(arch).reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    opt = AdamW()
    step = jax.jit(api.make_train_step(cfg, opt))
    p2, os2, metrics = step(params, opt.init(params), _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved
    states[arch] = (cfg, api, params)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch, states):
    if arch not in states:
        cfg = get_config(arch).reduce_for_smoke()
        api = model_api(cfg)
        params = api.init_params(cfg, jax.random.key(0))
    else:
        cfg, api, params = states[arch]
    batch = _batch(cfg)
    kw = {}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        kw["pad_cache_to"] = S + 8  # decode headroom
    cache, logits = jax.jit(lambda p, b: api.prefill(cfg, p, b, **kw))(params, batch)
    assert logits.shape[0] == B
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    dbatch = {"token": jnp.ones((B,), jnp.int32)}
    cache2, logits2 = jax.jit(lambda p, c, b: api.decode_step(cfg, p, c, b))(
        params, cache, dbatch
    )
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    np.testing.assert_array_equal(
        np.asarray(cache2["lengths"]), np.asarray(cache["lengths"]) + 1
    )


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_matches_init(arch):
    cfg = get_config(arch).reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(1))
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert api.param_count(cfg) == actual


@pytest.mark.parametrize("arch", ["arctic-480b", "grok-1-314b"])
def test_moe_active_params_below_total(arch):
    cfg = get_config(arch)
    api = model_api(cfg)
    assert api.active_param_count(cfg) < api.param_count(cfg)


def test_full_param_counts_sane():
    """Full configs land near their nameplate parameter counts."""
    expect = {
        "yi-6b": (5e9, 8e9),
        "llama3-8b": (7e9, 9e9),
        "smollm-135m": (1.2e8, 1.7e8),
        "h2o-danube-1.8b": (1.5e9, 2.2e9),
        "arctic-480b": (4.3e11, 5.3e11),
        "grok-1-314b": (2.8e11, 3.6e11),
        "mamba2-370m": (3.0e8, 4.5e8),
        "recurrentgemma-9b": (7.5e9, 1.15e10),
        "llava-next-34b": (3.0e10, 3.9e10),
        "whisper-small": (2.0e8, 3.6e8),  # SwiGLU + untied head stand-ins
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = model_api(cfg).param_count(cfg)
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_decode_matches_prefill_next_token():
    """Greedy next-token from decode == logits from prefill of seq+1 (dense)."""
    cfg = get_config("smollm-135m").reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) % cfg.vocab_size
    cache, _ = api.prefill(cfg, params, {"tokens": toks[:, :S]}, pad_cache_to=S + 4)
    _, dec_logits = api.decode_step(cfg, params, cache, {"token": toks[:, S]})
    full = api.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_swa_decode_ring_buffer():
    """SWA arch: decode with ring cache == full forward last-token logits
    once context exceeds the window."""
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b").reduce_for_smoke(), sliding_window=16
    )
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) % cfg.vocab_size
    cache, _ = api.prefill(cfg, params, {"tokens": toks[:, :S]})
    assert cache["k"].shape[3 - 1] == 16  # ring buffer is window-sized
    _, dec_logits = api.decode_step(cfg, params, cache, {"token": toks[:, S]})
    full = api.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_ssm_decode_matches_forward():
    cfg = get_config("mamba2-370m").reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) % cfg.vocab_size
    cache, _ = api.prefill(cfg, params, {"tokens": toks[:, :S]})
    _, dec_logits = api.decode_step(cfg, params, cache, {"token": toks[:, S]})
    full = api.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=5e-3, atol=5e-3,
    )


def test_grad_accum_matches_single_batch():
    """grad_accum=4 == grad_accum=1 (same global batch, same update)."""
    base = get_config("smollm-135m").reduce_for_smoke()
    api = model_api(base)
    params = api.init_params(base, jax.random.key(0))
    opt = AdamW()
    batch = _batch(base)  # B=2... need divisible: use B=4
    batch = {k: jnp.concatenate([v, v]) for k, v in batch.items()}
    cfgA = dataclasses.replace(base, grad_accum=1)
    cfgB = dataclasses.replace(base, grad_accum=4)
    pA, _, mA = jax.jit(api.make_train_step(cfgA, opt))(params, opt.init(params), batch)
    pB, _, mB = jax.jit(api.make_train_step(cfgB, opt))(params, opt.init(params), batch)
    np.testing.assert_allclose(float(mA["loss"]), float(mB["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-3, atol=2e-4,
        )


def test_encdec_decode_matches_forward():
    """Whisper decode step == full-forward last-token logits."""
    cfg = get_config("whisper-small").reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) % cfg.vocab_size
    frames = jnp.full((B, cfg.num_frames, cfg.d_model), 0.1, jnp.float32)
    batch = {"tokens": toks[:, :S], "frames": frames}
    cache, _ = api.prefill(cfg, params, batch, pad_cache_to=S + 4)
    _, dec_logits = api.decode_step(cfg, params, cache, {"token": toks[:, S]})
    full = api.forward(cfg, params, {"tokens": toks, "frames": frames})
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_hybrid_decode_matches_forward():
    """RecurrentGemma decode (RG-LRU states + attn ring) == full forward."""
    cfg = get_config("recurrentgemma-9b").reduce_for_smoke()
    api = model_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    toks = jnp.arange(B * (S + 1), dtype=jnp.int32).reshape(B, S + 1) % cfg.vocab_size
    cache, _ = api.prefill(cfg, params, {"tokens": toks[:, :S]})
    _, dec_logits = api.decode_step(cfg, params, cache, {"token": toks[:, S]})
    full = api.forward(cfg, params, {"tokens": toks})
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full[:, -1], np.float32),
        rtol=5e-3, atol=5e-3,
    )
