"""StatsBoard / PredicateStats / ReuseCache unit tests (§3.3, §4.3)."""
import os

import numpy as np

from repro.core import ReuseCache
from repro.core.stats import Ema, PredicateStats, StatsBoard


def test_ema_converges():
    e = Ema(alpha=0.5)
    for _ in range(20):
        e.update(10.0)
    assert abs(e.get() - 10.0) < 1e-6


def test_cost_per_row_ema():
    st = PredicateStats("p")
    st.record_eval(10, 5, seconds=0.1)   # 10ms/row
    st.record_eval(10, 5, seconds=0.3)   # 30ms/row
    assert 0.01 < st.cost() < 0.03       # EMA between the two


def test_lottery_selectivity():
    st = PredicateStats("p")
    st.record_eval(100, 25, seconds=0.1)
    assert st.selectivity() == 0.25
    st.record_eval(100, 75, seconds=0.1)
    assert st.selectivity() == 0.5


def test_score_formula():
    st = PredicateStats("p")
    st.record_eval(100, 50, 100 * 0.002)  # cost 2ms/row, sel 0.5
    assert abs(st.score() - 0.002 / 0.5) < 1e-9


def test_worker_load_accounting():
    sb = StatsBoard(["p"])
    sb.add_load("w0", 10.0)
    sb.add_load("w0", 5.0)
    sb.finish_load("w0", 10.0)
    assert sb.load_of("w0") == 5.0
    sb.finish_load("w0", 99.0)
    assert sb.load_of("w0") == 0.0  # clamped


def test_cache_probe_put():
    c = ReuseCache()
    ids = np.array([1, 5, 9])
    hits, _ = c.probe("udf", ids)
    assert not hits.any()
    c.put("udf", ids, np.array([10.0, 50.0, 90.0]))
    hits, vals = c.probe("udf", np.array([5, 6, 9]))
    np.testing.assert_array_equal(hits, [True, False, True])
    assert vals[0] == 50.0 and vals[2] == 90.0
    assert c.hit_rate("udf", np.array([1, 2, 3, 5])) == 0.5


def test_cache_disk_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "cache.npz")
    c = ReuseCache(path)
    c.put("udf", np.arange(4), np.arange(4) * 2.0)
    c.flush()
    c2 = ReuseCache(path)
    hits, vals = c2.probe("udf", np.array([2, 3]))
    assert hits.all() and vals[0] == 4.0 and vals[1] == 6.0


def test_cache_vector_values():
    c = ReuseCache()
    c.put("udf", np.array([7]), np.ones((1, 4)))
    hits, vals = c.probe("udf", np.array([7]))
    assert hits.all() and vals[0].shape == (4,)
