"""StatsBoard / PredicateStats / ReuseCache unit tests (§3.3, §4.3).

Includes the ReuseCache hardening regressions (extension-less path,
ragged flush, atomic flush + corrupt-tolerant load, vectorized/values-free
probing) and the content-hash + layered cache TTL/invalidation semantics."""
import os

import numpy as np
import pytest

from repro.core import ContentHashCache, LayeredReuseCache, ReuseCache
from repro.core.cache import row_digests
from repro.core.stats import Ema, PredicateStats, StatsBoard


def test_ema_converges():
    e = Ema(alpha=0.5)
    for _ in range(20):
        e.update(10.0)
    assert abs(e.get() - 10.0) < 1e-6


def test_cost_per_row_ema():
    st = PredicateStats("p")
    st.record_eval(10, 5, seconds=0.1)   # 10ms/row
    st.record_eval(10, 5, seconds=0.3)   # 30ms/row
    assert 0.01 < st.cost() < 0.03       # EMA between the two


def test_lottery_selectivity():
    st = PredicateStats("p")
    st.record_eval(100, 25, seconds=0.1)
    assert st.selectivity() == 0.25
    st.record_eval(100, 75, seconds=0.1)
    assert st.selectivity() == 0.5


def test_score_formula():
    st = PredicateStats("p")
    st.record_eval(100, 50, 100 * 0.002)  # cost 2ms/row, sel 0.5
    assert abs(st.score() - 0.002 / 0.5) < 1e-9


def test_worker_load_accounting():
    sb = StatsBoard(["p"])
    sb.add_load("w0", 10.0)
    sb.add_load("w0", 5.0)
    sb.finish_load("w0", 10.0)
    assert sb.load_of("w0") == 5.0
    sb.finish_load("w0", 99.0)
    assert sb.load_of("w0") == 0.0  # clamped


def test_cache_probe_put():
    c = ReuseCache()
    ids = np.array([1, 5, 9])
    hits, _ = c.probe("udf", ids)
    assert not hits.any()
    c.put("udf", ids, np.array([10.0, 50.0, 90.0]))
    hits, vals = c.probe("udf", np.array([5, 6, 9]))
    np.testing.assert_array_equal(hits, [True, False, True])
    assert vals[0] == 50.0 and vals[2] == 90.0
    assert c.hit_rate("udf", np.array([1, 2, 3, 5])) == 0.5


def test_cache_disk_roundtrip(tmp_path):
    path = os.path.join(tmp_path, "cache.npz")
    c = ReuseCache(path)
    c.put("udf", np.arange(4), np.arange(4) * 2.0)
    c.flush()
    c2 = ReuseCache(path)
    hits, vals = c2.probe("udf", np.array([2, 3]))
    assert hits.all() and vals[0] == 4.0 and vals[1] == 6.0


def test_cache_vector_values():
    c = ReuseCache()
    c.put("udf", np.array([7]), np.ones((1, 4)))
    hits, vals = c.probe("udf", np.array([7]))
    assert hits.all() and vals[0].shape == (4,)


# ------------------- ReuseCache hardening regressions ------------------- #
def test_cache_path_without_npz_extension_roundtrips(tmp_path):
    """np.savez appends .npz on write; an un-normalized path used to read
    the literal (absent) file and silently start the next process cold."""
    path = os.path.join(tmp_path, "cache")  # no extension
    c = ReuseCache(path)
    c.put("udf", np.arange(4), np.arange(4) * 2.0)
    c.flush()
    assert os.path.exists(os.path.join(tmp_path, "cache.npz"))
    c2 = ReuseCache(path)
    hits, vals = c2.probe("udf", np.array([2, 3]))
    assert hits.all() and vals[0] == 4.0 and vals[1] == 6.0


def test_cache_flush_ragged_values_roundtrip(tmp_path):
    """Heterogeneous shapes per UDF (variable-length detector boxes) used
    to crash flush's unconditional np.stack with ValueError."""
    path = os.path.join(tmp_path, "ragged.npz")
    c = ReuseCache(path)
    c.put("det", np.array([1]), [np.ones((2, 4))])       # 2 boxes
    c.put("det", np.array([2]), [np.zeros((5, 4))])      # 5 boxes
    c.put("det", np.array([3]), [np.full((2, 4), 7.0)])  # 2 boxes again
    c.put("scalar", np.array([9]), np.array([3.5]))
    c.flush()
    c2 = ReuseCache(path)
    hits, vals = c2.probe("det", np.array([1, 2, 3]))
    assert hits.all()
    np.testing.assert_array_equal(vals[0], np.ones((2, 4)))
    np.testing.assert_array_equal(vals[1], np.zeros((5, 4)))
    np.testing.assert_array_equal(vals[2], np.full((2, 4), 7.0))
    _, svals = c2.probe("scalar", np.array([9]))
    assert svals[0] == 3.5


def test_cache_flush_atomic_under_midwrite_crash(tmp_path, monkeypatch):
    """A crash mid-flush must leave the PREVIOUS snapshot readable."""
    path = os.path.join(tmp_path, "atomic.npz")
    c = ReuseCache(path)
    c.put("udf", np.arange(3), np.arange(3) * 1.0)
    c.flush()

    def boom(*a, **kw):
        raise OSError("disk died mid-write")

    monkeypatch.setattr(np, "savez", boom)
    c.put("udf", np.array([99]), np.array([99.0]))
    with pytest.raises(OSError):
        c.flush()
    monkeypatch.undo()
    c2 = ReuseCache(path)  # old snapshot intact, loads clean
    hits, vals = c2.probe("udf", np.array([0, 1, 2]))
    assert hits.all() and vals[2] == 2.0


def test_cache_load_corrupt_file_starts_cold(tmp_path):
    """A corrupt/empty snapshot warns and starts cold instead of raising
    at construction (the old _load let ZipFile errors escape)."""
    path = os.path.join(tmp_path, "corrupt.npz")
    with open(path, "wb") as f:
        f.write(b"not an npz at all")
    with pytest.warns(UserWarning, match="starting cold"):
        c = ReuseCache(path)
    assert c.size("udf") == 0
    c.put("udf", np.array([1]), np.array([1.0]))
    c.flush()  # and the path is usable again
    assert ReuseCache(path).size("udf") == 1

    with open(path, "wb"):
        pass  # zero-byte file
    with pytest.warns(UserWarning, match="starting cold"):
        assert ReuseCache(path).size("udf") == 0


def test_hit_rate_is_values_free(monkeypatch):
    """hit_rate must not call probe (the old one materialized every value
    and threw it away on the ReuseAware routing hot path)."""
    c = ReuseCache()
    c.put("udf", np.arange(10), np.arange(10) * 1.0)

    def no_probe(*a, **kw):
        raise AssertionError("hit_rate must not materialize values")

    monkeypatch.setattr(c, "probe", no_probe)
    assert c.hit_rate("udf", np.array([0, 1, 20, 21])) == 0.5
    assert c.hit_rate("udf", np.array([])) == 0.0


def test_vectorized_probe_matches_dict_semantics():
    c = ReuseCache()
    ids = np.array([5, 1, 9, 1, 400])  # unsorted, duplicated
    c.put("udf", np.array([1, 9]), np.array([10.0, 90.0]))
    hits, vals = c.probe("udf", ids)
    np.testing.assert_array_equal(hits, [False, True, True, True, False])
    assert vals[1] == 10.0 and vals[2] == 90.0 and vals[3] == 10.0
    assert vals[0] is None and vals[4] is None
    # probing a udf never written stays all-miss
    hits, _ = c.probe("other", ids)
    assert not hits.any()


def test_cache_invalidate():
    c = ReuseCache()
    c.put("a", np.arange(3), np.arange(3) * 1.0)
    c.put("b", np.arange(3), np.arange(3) * 1.0)
    c.invalidate("a")
    assert c.size("a") == 0 and c.size("b") == 3
    c.invalidate()
    assert c.size("b") == 0


# --------------------- content-hash cache semantics --------------------- #
def _payload(rids):
    return {"rid": np.asarray(rids)}


def test_row_digests_content_identity():
    a = row_digests(_payload([1, 2, 3]))
    b = row_digests(_payload([1, 2, 3]))
    np.testing.assert_array_equal(a, b)          # deterministic
    assert a[0] != a[1]                          # distinct content differs
    # dtype and column name are part of the digest
    x = row_digests({"c": np.array([1], np.int64)})
    assert row_digests({"c": np.array([1.0])})[0] != x[0]
    assert row_digests({"d": np.array([1], np.int64)})[0] != x[0]


def test_content_cache_hits_across_row_ids():
    """The tentpole semantics: identical payload under FRESH row ids hits."""
    c = ContentHashCache()
    c.put_batch("udf", np.arange(3), _payload([10, 11, 12]), np.arange(3.0))
    hits, vals = c.probe_batch("udf", np.arange(3) + 1000,
                               _payload([10, 11, 12]))
    assert hits.all() and vals[0] == 0.0 and vals[2] == 2.0
    hits, _ = c.probe_batch("udf", np.arange(2), _payload([10, 99]))
    np.testing.assert_array_equal(hits, [True, False])


def test_content_cache_ttl_expiry():
    now = [0.0]
    c = ContentHashCache(ttl_s=10.0, clock=lambda: now[0])
    c.put_batch("udf", np.arange(2), _payload([1, 2]), np.ones(2))
    assert c.hit_rate("udf", np.arange(2), data=_payload([1, 2])) == 1.0
    now[0] = 9.0
    assert c.hit_rate("udf", np.arange(2), data=_payload([1, 2])) == 1.0
    now[0] = 11.0  # past TTL: read as miss and evict lazily
    assert c.hit_rate("udf", np.arange(2), data=_payload([1, 2])) == 0.0
    hits, _ = c.probe_batch("udf", np.arange(2), _payload([1, 2]))
    assert not hits.any()
    assert c.size("udf") == 0  # probe evicted the expired entries


def test_content_cache_explicit_invalidation():
    c = ContentHashCache()
    c.put_batch("a", np.arange(2), _payload([1, 2]), np.ones(2))
    c.put_batch("b", np.arange(2), _payload([1, 2]), np.ones(2))
    c.invalidate("a")
    assert c.size("a") == 0 and c.size("b") == 2
    c.invalidate()
    assert c.size("b") == 0


# ------------------------- layered composition ------------------------- #
def test_layered_cache_content_fallthrough_and_promotion():
    lc = LayeredReuseCache()
    lc.put_batch("udf", np.arange(3), _payload([7, 8, 9]), np.arange(3.0))
    # fresh row ids: the id layer misses, the content layer hits
    new_ids = np.arange(3) + 500
    assert lc.ids.hit_mask("udf", new_ids).sum() == 0
    hits, vals = lc.probe_batch("udf", new_ids, _payload([7, 8, 9]))
    assert hits.all() and vals[1] == 1.0
    # promotion: the id layer now answers for the new ids directly
    assert lc.ids.hit_mask("udf", new_ids).all()


def test_layered_hit_rate_folds_both_layers():
    lc = LayeredReuseCache()
    lc.put_batch("udf", np.arange(4), _payload([0, 1, 2, 3]), np.ones(4))
    # 2 id-hits + 1 content-hit (payload 3 under a new id) + 1 true miss
    ids = np.array([0, 1, 600, 601])
    rate = lc.hit_rate("udf", ids, data=_payload([0, 1, 3, 99]))
    assert rate == 0.75
    # without payload data only the id layer answers
    assert lc.hit_rate("udf", ids) == 0.5


def test_layered_cache_disk_spill_ids_layer(tmp_path):
    path = os.path.join(tmp_path, "layered")
    lc = LayeredReuseCache(path)
    lc.put_batch("udf", np.arange(2), _payload([1, 2]), np.ones(2))
    lc.flush()
    lc2 = LayeredReuseCache(path)
    assert lc2.ids.hit_mask("udf", np.arange(2)).all()


def test_layered_invalidate_clears_both_layers():
    lc = LayeredReuseCache()
    lc.put_batch("udf", np.arange(2), _payload([1, 2]), np.ones(2))
    lc.invalidate("udf")
    hits, _ = lc.probe_batch("udf", np.arange(2), _payload([1, 2]))
    assert not hits.any()
