"""Beyond-paper extension: content-based routing [Bizarro et al. 2005].

The paper's §2.2 credits content-based routing with better plans than
average-statistics Eddies but rejects it for tuple-granularity overhead;
Hydro's routing BATCHES amortize that overhead away, so this benchmark
adds it as a policy and measures the win on content-correlated predicates:

  rows carry a 'size' attribute; predicate A drops LARGE rows, predicate B
  drops SMALL rows (equal costs). Batches are size-homogeneous (the camera
  scene changes slowly — the paper's own bbox-dimension observation).
  Global-statistics policies see sel_A == sel_B == 0.5 and pick an
  arbitrary fixed order; content-based routing learns the per-bucket
  selectivities and orders per batch.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import AQPExecutor, Predicate, ScoreDriven, SimClock, UDF, make_batch
from repro.core.policies import ContentBased

N_BATCHES = 80
ROWS = 10
COST = 0.010  # s/row, both predicates


def build(seed=0):
    rng = np.random.default_rng(seed)
    batches = []
    for i in range(N_BATCHES):
        small = i % 2 == 0  # size-homogeneous batches, alternating scenes
        size = rng.uniform(10, 20, ROWS) if small else rng.uniform(80, 100, ROWS)
        batches.append(make_batch(
            {"size": size.astype(np.float32)},
            np.arange(i * ROWS, (i + 1) * ROWS),
        ))

    def mk(name, passes_small):
        def fn(d):
            is_small = d["size"] < 50
            return is_small if passes_small else ~is_small

        udf = UDF(name, fn=fn, columns=("size",), resource=f"r_{name}",
                  cost_model=lambda rows: rows * COST, bucket=False)
        return Predicate(name, udf, compare=lambda o: o.astype(bool))

    # A passes small rows (drops large); B passes large rows (drops small).
    return mk("A", True), mk("B", False), batches


def bucket_fn(batch):
    return int(batch.data["size"].mean() >= 50)


def run(policy):
    A, B, batches = build()
    clk = SimClock()
    ex = AQPExecutor([A, B], policy=policy, clock=clk, max_workers=1)
    out = sum(b.rows for b in ex.run(iter(batches)))
    assert out == 0  # A AND B is unsatisfiable: every row dropped early
    return ex.makespan


def main() -> None:
    t_score = run(ScoreDriven())
    t_content = run(ContentBased(bucket_fn))
    record("content/score_driven", t_score * 1e6, f"sim_makespan_s={t_score:.3f}")
    record("content/content_based", t_content * 1e6,
           f"sim_makespan_s={t_content:.3f}")
    record("content/content_vs_score", 0.0, f"{t_score/t_content:.2f}x")
    # ideal: always run the dropping predicate first -> each batch costs ~1
    # unit instead of ~1.5 on average for a fixed global order
    assert t_content < t_score * 0.85, (t_content, t_score)


if __name__ == "__main__":
    main()
