"""Fig. 7 reproduction: synthetic two-predicate sweep.

Predicates A (10ms/row) and B (20ms/row) on separate resources; selectivity
of B in {0.1, 0.5, 0.9}, selectivity of A swept 0.1..0.9. Reports the
speedup of cost-driven routing over score-driven and selectivity-driven.
Paper claim: cost-driven is NEVER worse, and wins most when the high-cost
predicate has low selectivity.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, Predicate, ScoreDriven, SelectivityDriven,
    SimClock, UDF, make_batch,
)

COST_A, COST_B = 0.010, 0.020
N_ROWS = 300


def build(sel_a: float, sel_b: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    a_pass = frozenset(rng.choice(N_ROWS, int(N_ROWS * sel_a), replace=False).tolist())
    b_pass = frozenset(rng.choice(N_ROWS, int(N_ROWS * sel_b), replace=False).tolist())

    def mk(name, ids, cost, res):
        udf = UDF(name, fn=lambda d: np.isin(d["rid"], list(ids)),
                  columns=("rid",), resource=res,
                  cost_model=lambda rows: rows * cost, bucket=False)
        return Predicate(name, udf, compare=lambda o: o.astype(bool))

    A = mk("A", a_pass, COST_A, "cpu")
    B = mk("B", b_pass, COST_B, "tpu:0")
    batches = [
        make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
        for i in range(0, N_ROWS, 10)
    ]
    return A, B, batches, a_pass & b_pass


def run(policy_cls, sel_a, sel_b):
    A, B, batches, expect = build(sel_a, sel_b)
    clk = SimClock()
    ex = AQPExecutor([A, B], policy=policy_cls(), clock=clk, max_workers=1)
    got = {int(i) for b in ex.run(iter(batches)) for i in b.row_ids}
    assert got == expect
    return ex.makespan


def main() -> None:
    regressions = []
    for sel_b in (0.1, 0.5, 0.9):
        for sel_a in np.linspace(0.1, 0.9, 9):
            sel_a = round(float(sel_a), 1)
            t_cost = run(CostDriven, sel_a, sel_b)
            t_score = run(ScoreDriven, sel_a, sel_b)
            t_sel = run(SelectivityDriven, sel_a, sel_b)
            record(
                f"uc1_synth/selB={sel_b}/selA={sel_a}",
                t_cost * 1e6,
                f"speedup_vs_score={t_score/t_cost:.3f}x;"
                f"speedup_vs_selectivity={t_sel/t_cost:.3f}x",
            )
            if t_cost > min(t_score, t_sel) * 1.02:
                regressions.append((sel_a, sel_b, t_cost, t_score, t_sel))
    # paper claim: cost-driven never worse (2% scheduling noise allowed)
    assert not regressions, regressions
    record("uc1_synth/never_worse", 0.0, "PASS")


if __name__ == "__main__":
    main()
