"""Sharded eddy routing throughput: N shards vs the single-shard loop.

The workload is the regime ISSUE/ROADMAP describe — per-batch UDF eval
cost in the handful-of-milliseconds band where the ROUTING loop, not
evaluation, caps utilization: P pass-all predicates with heterogeneous
sleep-based eval costs (5–19 ms full mode), ONE worker each (so stage
capacity is fixed and scale-up noise is out of the picture), warmup off,
cost-driven ranking. A single routing shard serializes every blocked
``LaminarRouter.submit`` — it waits on ONE full worker queue while the
other workers' queues drain empty (head-of-line blocking). N shards keep
N blocked submits in flight, which is exactly the overlap the sharded
eddy core buys; heterogeneous per-predicate costs keep the batch stream
from marching through the stages in lockstep waves that would re-serialize
the shards behind one hot queue.

Correctness gates in BOTH modes: every shard count must complete the same
row-id MULTISET (nothing lost, nothing duplicated) and the same batch
count as the single-shard run. Timing gates (2-shard >= 1.7x, 4-shard >=
2.5x) are enforced only in FULL mode on a host with >= 4 CPU cores: on
a 1-core host the only parallelism available is overlapping blocked
waits under the GIL, which tops out well below the multi-core ratios
(the numbers are still recorded, honestly, with the core count).

Modes (env ROUTING_BENCH_MODE or ``main(mode=...)``):
  smoke — CI-sized (1–3.8 ms sleeps, 24 batches, ~5 s total); regenerates
          BENCH_routing.json so the artifact always matches the harness.
  full  — the committed-artifact run (5–19 ms sleeps, 120 batches).

The artifact is written by THIS harness (never hand-edited): repo-root
BENCH_routing.json, one entry per shard count plus host metadata.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import List, Optional

import numpy as np

from benchmarks.harness import record
from repro.core import AQPExecutor, CostDriven, Predicate, UDF, make_batch

ROWS_PER_BATCH = 8
SHARD_COUNTS = (1, 2, 4)
CENTRAL_CAPACITY = 128  # deep watermark: keep the pipeline saturated

# full mode: the committed-artifact workload (see module docstring)
FULL_SLEEPS_S = (0.005, 0.007, 0.009, 0.011, 0.013, 0.015, 0.017, 0.019)
FULL_BATCHES = 120
# smoke mode: same shape, CI-sized
SMOKE_SLEEPS_S = tuple(round(s / 5, 4) for s in FULL_SLEEPS_S)
SMOKE_BATCHES = 24

# timing gates — enforced only in full mode on a >= 4-core host
MIN_SPEEDUP = {2: 1.7, 4: 2.5}
GATE_MIN_CORES = 4

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "BENCH_routing.json")


def build_predicates(sleeps_s) -> List[Predicate]:
    preds = []
    for i, sleep_s in enumerate(sleeps_s):
        def fn(cols, _s=sleep_s):
            time.sleep(_s)  # stands in for a GIL-releasing accelerator call
            return np.ones(len(cols["x"]), dtype=bool)

        udf = UDF(name=f"p{i}", fn=fn, columns=("x",), bucket=False,
                  resource=f"r{i}")
        preds.append(Predicate(name=f"p{i}", udf=udf,
                               compare=lambda out: out.astype(bool)))
    return preds


def build_batches(n: int):
    out = []
    for b in range(n):
        x = np.arange(b * ROWS_PER_BATCH, (b + 1) * ROWS_PER_BATCH)
        out.append(make_batch({"x": x}, row_ids=x))
    return out


def run_once(shards: int, sleeps_s, n_batches: int):
    ex = AQPExecutor(
        build_predicates(sleeps_s),
        policy=CostDriven(),
        max_workers=1,          # fixed stage capacity: no scale-up noise
        warmup=False,
        shards=shards,
        central_capacity=CENTRAL_CAPACITY,
    )
    t0 = time.perf_counter()
    done = ex.collect(build_batches(n_batches))
    elapsed = time.perf_counter() - t0
    row_ids = collections.Counter()
    for b in done:
        row_ids.update(b.row_ids.tolist())
    routing = ex.stats_snapshot()["_routing"]
    return {
        "shards": shards,
        "batches": len(done),
        "elapsed_s": elapsed,
        "batches_per_s": n_batches / elapsed,
        "steals": routing["steals"],
        "circulations": routing["circulations"],
        "shards_active": routing["shards_active"],
    }, row_ids


def main(mode: Optional[str] = None) -> dict:
    mode = mode or os.environ.get("ROUTING_BENCH_MODE", "smoke")
    assert mode in ("smoke", "full"), mode
    sleeps = FULL_SLEEPS_S if mode == "full" else SMOKE_SLEEPS_S
    n = FULL_BATCHES if mode == "full" else SMOKE_BATCHES
    cores = os.cpu_count() or 1

    runs, baseline_rows, baseline_bps = [], None, None
    for shards in SHARD_COUNTS:
        result, row_ids = run_once(shards, sleeps, n)
        if baseline_rows is None:
            baseline_rows, baseline_bps = row_ids, result["batches_per_s"]
        else:
            result["speedup"] = result["batches_per_s"] / baseline_bps
            # correctness gate, BOTH modes: the sharded run completed the
            # exact same row-id multiset — nothing lost, nothing duplicated
            assert row_ids == baseline_rows, (
                f"{shards}-shard run lost/duplicated rows vs single-shard: "
                f"only-in-sharded={row_ids - baseline_rows} "
                f"only-in-single={baseline_rows - row_ids}"
            )
        assert result["batches"] == n, (shards, result["batches"], n)
        runs.append(result)
        record(
            f"routing/shards{shards}",
            result["elapsed_s"] / n * 1e6,
            f"bps={result['batches_per_s']:.1f};steals={result['steals']}"
            + (f";speedup={result['speedup']:.2f}x" if "speedup" in result else ""),
        )

    gates_enforced = mode == "full" and cores >= GATE_MIN_CORES
    artifact = {
        "benchmark": "routing_throughput",
        "mode": mode,
        "n_preds": len(sleeps),
        "eval_sleep_s": list(sleeps),
        "n_batches": n,
        "rows_per_batch": ROWS_PER_BATCH,
        "cpu_count": cores,
        "row_id_multiset_match": True,  # asserted above for every run
        "runs": runs,
        "gates": {
            "min_speedup": {str(k): v for k, v in MIN_SPEEDUP.items()},
            "enforced": gates_enforced,
            "reason": (
                "full mode on a >= 4-core host" if gates_enforced else
                f"timing non-gating: mode={mode}, cpu_count={cores} "
                f"(thresholds apply in full mode on >= {GATE_MIN_CORES} cores)"
            ),
        },
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    record("routing/artifact", 0.0, os.path.normpath(ARTIFACT))

    if gates_enforced:
        for r in runs:
            want = MIN_SPEEDUP.get(r["shards"])
            if want is not None:
                assert r["speedup"] >= want, (
                    f"{r['shards']}-shard speedup {r['speedup']:.2f}x "
                    f"below the {want}x gate on a {cores}-core host"
                )
    return artifact


if __name__ == "__main__":
    main(mode=os.environ.get("ROUTING_BENCH_MODE"))
