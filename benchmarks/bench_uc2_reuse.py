"""UC2 (paper Fig. 8/9): reuse-aware routing with partial caches.

Exploratory queries Q1 (ObjectDetector on frames 1000..7000) and Q2
(HardHatDetector on frames 8000..14000) populate the cache; the recurrent
query Q3 (both predicates, all frames) then runs under three variants:

  baseline (static order) | +cost-driven | +reuse-aware cost-driven

Paper claims: reuse-aware beats baseline (~1.25x) AND beats blind
cost-driven (~1.41x); blind cost-driven can be SLOWER than baseline because
its cost estimate lags across cache-boundary segments (Fig 9a).
Also emits the Fig 9 analogue: per-segment estimated predicate costs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, Predicate, ReuseAware, ReuseCache, SimClock,
    UDF, make_batch,
)
from repro.core.policies import EddyPolicy

N_FRAMES = 1400           # scaled 10x down from the paper's 14000
SEG = N_FRAMES // 14      # segment unit (paper: 1000 frames)
OBJ_COST = 0.020
HAT_COST = 0.020


class FixedOrder(EddyPolicy):
    name = "fixed"

    def rank(self, batch, preds, stats, cache):
        return preds


def make_preds(seed=0):
    rng = np.random.default_rng(seed)
    person = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.5), replace=False).tolist())
    nohat = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.3), replace=False).tolist())

    def mk(name, ids, cost):
        udf = UDF(name, fn=lambda d: np.isin(d["rid"], list(ids)),
                  columns=("rid",), resource="tpu:0" if name == "obj" else "tpu:1",
                  cost_model=lambda rows: rows * cost, bucket=False)
        return Predicate(name, udf, compare=lambda o: o.astype(bool))

    return mk("obj", person, OBJ_COST), mk("hat", nohat, HAT_COST), person & nohat


def batches():
    return [
        make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
        for i in range(0, N_FRAMES, 10)
    ]


def prime_cache(cache: ReuseCache, obj: Predicate, hat: Predicate):
    """Q1 and Q2: cache obj on frames [SEG, 7*SEG), hat on [8*SEG, 14*SEG)."""
    r1 = np.arange(SEG, 7 * SEG)
    cache.put(obj.udf.name, r1, obj.udf({"rid": r1}))
    r2 = np.arange(8 * SEG, 14 * SEG)
    cache.put(hat.udf.name, r2, hat.udf({"rid": r2}))


def run(policy, *, use_cache: bool, warmup=True, track=None):
    obj, hat, expect = make_preds()
    cache = ReuseCache()
    prime_cache(cache, obj, hat)
    clk = SimClock()
    # cost_alpha=0.02: long-horizon cost averaging, the paper's Fig 9a
    # estimator that "cannot promptly adjust" across cache boundaries —
    # this lag is precisely what reuse-aware routing fixes.
    ex = AQPExecutor([obj, hat], policy=policy, clock=clk, max_workers=1,
                     cache=cache if use_cache else None, warmup=warmup,
                     cost_alpha=0.02)
    got = set()
    for b in ex.run(iter(batches())):
        got |= {int(i) for i in b.row_ids}
    assert got == expect
    if track is not None:
        track.append(ex.stats_snapshot())
    return ex.makespan


def main() -> None:
    t_base = run(FixedOrder(), use_cache=True, warmup=False)
    t_cost = run(CostDriven(), use_cache=True)
    t_reuse = run(ReuseAware(), use_cache=True)
    record("uc2/baseline_cached", t_base * 1e6, f"sim_makespan_s={t_base:.3f}")
    record("uc2/cost_driven", t_cost * 1e6, f"sim_makespan_s={t_cost:.3f}")
    record("uc2/reuse_aware", t_reuse * 1e6, f"sim_makespan_s={t_reuse:.3f}")
    record("uc2/reuse_vs_baseline", 0.0, f"{t_base/t_reuse:.2f}x")
    record("uc2/reuse_vs_cost", 0.0, f"{t_cost/t_reuse:.2f}x")
    assert t_reuse < t_base, (t_reuse, t_base)
    assert t_reuse < t_cost, (t_reuse, t_cost)

    # Fig 9 analogue: reuse-aware estimated cost per segment
    obj, hat, _ = make_preds()
    cache = ReuseCache()
    prime_cache(cache, obj, hat)
    ra = ReuseAware()
    from repro.core.stats import StatsBoard

    sb = StatsBoard(["obj", "hat"])
    sb["obj"].cost_per_row.update(OBJ_COST)
    sb["hat"].cost_per_row.update(HAT_COST)
    sb["obj"].batches = sb["hat"].batches = 1
    for seg in range(14):
        rid = np.arange(seg * SEG, (seg + 1) * SEG)
        b = make_batch({"rid": rid}, rid)
        eo = ra.est_cost(b, obj, sb, cache)
        eh = ra.est_cost(b, hat, sb, cache)
        record(f"uc2/fig9/segment{seg:02d}", 0.0,
               f"est_obj={eo*1e3:.2f}ms;est_hat={eh*1e3:.2f}ms;"
               f"routes_to={'obj' if eo <= eh else 'hat'}")


if __name__ == "__main__":
    main()
