"""UC2 (paper Fig. 8/9): reuse-aware routing with partial caches.

Exploratory queries Q1 (ObjectDetector on frames 1000..7000) and Q2
(HardHatDetector on frames 8000..14000) populate the cache; the recurrent
query Q3 (both predicates, all frames) then runs under three variants:

  baseline (static order) | +cost-driven | +reuse-aware cost-driven

Paper claims: reuse-aware beats baseline (~1.25x) AND beats blind
cost-driven (~1.41x); blind cost-driven can be SLOWER than baseline because
its cost estimate lags across cache-boundary segments (Fig 9a).
Also emits the Fig 9 analogue: per-segment estimated predicate costs.

REPEATED-QUERY TRACE (cross-query reuse tentpole): the same logical query
re-issued N times, each re-scan ingesting IDENTICAL frame payloads under
FRESH row ids (a new scan's ids never match an old scan's). Three
variants:

  cold        — every query cold-starts statistics and cache (the
                pre-statstore behavior);
  warm-stats  — a shared StatsStore warm-starts each run's StatsBoard
                from the previous run's profiled cost/selectivity, so
                repeats skip the warmup circulation;
  warm-full   — warm-stats + a shared LayeredReuseCache whose
                content-hash layer hits on the identical payloads despite
                the fresh row ids, skipping evaluation entirely.

Gate: warm-full must be >= 1.3x faster than cold over the trace
(asserted; run as a CI smoke step via ``benchmarks.run --only uc2_repeat``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, LayeredReuseCache, Predicate, ReuseAware,
    ReuseCache, SimClock, StatsStore, UDF, make_batch,
)
from repro.core.policies import EddyPolicy

N_FRAMES = 1400           # scaled 10x down from the paper's 14000
SEG = N_FRAMES // 14      # segment unit (paper: 1000 frames)
OBJ_COST = 0.020
HAT_COST = 0.020
N_REPEATS = 3             # repeated-query trace length
REPEAT_SPEEDUP_GATE = 1.3


class FixedOrder(EddyPolicy):
    name = "fixed"

    def rank(self, batch, preds, stats, cache):
        return preds


def make_preds(seed=0):
    rng = np.random.default_rng(seed)
    person = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.5), replace=False).tolist())
    nohat = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.3), replace=False).tolist())

    def mk(name, ids, cost):
        udf = UDF(name, fn=lambda d: np.isin(d["rid"], list(ids)),
                  columns=("rid",), resource="tpu:0" if name == "obj" else "tpu:1",
                  cost_model=lambda rows: rows * cost, bucket=False)
        return Predicate(name, udf, compare=lambda o: o.astype(bool))

    return mk("obj", person, OBJ_COST), mk("hat", nohat, HAT_COST), person & nohat


def batches():
    return [
        make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
        for i in range(0, N_FRAMES, 10)
    ]


def prime_cache(cache: ReuseCache, obj: Predicate, hat: Predicate):
    """Q1 and Q2: cache obj on frames [SEG, 7*SEG), hat on [8*SEG, 14*SEG)."""
    r1 = np.arange(SEG, 7 * SEG)
    cache.put(obj.udf.name, r1, obj.udf({"rid": r1}))
    r2 = np.arange(8 * SEG, 14 * SEG)
    cache.put(hat.udf.name, r2, hat.udf({"rid": r2}))


def run(policy, *, use_cache: bool, warmup=True, track=None):
    obj, hat, expect = make_preds()
    cache = ReuseCache()
    prime_cache(cache, obj, hat)
    clk = SimClock()
    # cost_alpha=0.02: long-horizon cost averaging, the paper's Fig 9a
    # estimator that "cannot promptly adjust" across cache boundaries —
    # this lag is precisely what reuse-aware routing fixes.
    ex = AQPExecutor([obj, hat], policy=policy, clock=clk, max_workers=1,
                     cache=cache if use_cache else None, warmup=warmup,
                     cost_alpha=0.02)
    got = set()
    for b in ex.run(iter(batches())):
        got |= {int(i) for i in b.row_ids}
    assert got == expect
    if track is not None:
        track.append(ex.stats_snapshot())
    return ex.makespan


def _trace_query(repeat: int, *, cache, store) -> float:
    """One re-issue of the query: identical payloads, fresh scan row ids."""
    obj, hat, expect = make_preds()
    off = repeat * N_FRAMES  # a new scan never reuses an old scan's ids
    src = [
        make_batch({"rid": np.arange(i, i + 10)},
                   np.arange(i, i + 10) + off)
        for i in range(0, N_FRAMES, 10)
    ]
    ex = AQPExecutor([obj, hat], policy=ReuseAware(), clock=SimClock(),
                     max_workers=1, cache=cache, warmup=True,
                     stats_store=store)
    got = set()
    for b in ex.run(iter(src)):
        got |= {int(i) for i in b.row_ids}
    assert got == {r + off for r in expect}
    return ex.makespan


def repeated_query_trace() -> None:
    """Warm-start + content-hash cache win on the repeated trace (>=1.3x)."""
    t_cold = sum(
        _trace_query(k, cache=LayeredReuseCache(), store=None)
        for k in range(N_REPEATS)
    )
    store = StatsStore()
    t_stats = sum(
        _trace_query(k, cache=LayeredReuseCache(), store=store)
        for k in range(N_REPEATS)
    )
    store_full, shared_cache = StatsStore(), LayeredReuseCache()
    t_warm = sum(
        _trace_query(k, cache=shared_cache, store=store_full)
        for k in range(N_REPEATS)
    )
    record("uc2_repeat/cold", t_cold * 1e6,
           f"sim_makespan_s={t_cold:.3f};repeats={N_REPEATS}")
    record("uc2_repeat/warm_stats", t_stats * 1e6,
           f"sim_makespan_s={t_stats:.3f}")
    record("uc2_repeat/warm_full", t_warm * 1e6,
           f"sim_makespan_s={t_warm:.3f}")
    record("uc2_repeat/warm_vs_cold", 0.0, f"{t_cold / t_warm:.2f}x")
    record("uc2_repeat/content_hits", 0.0,
           f"content_entries={shared_cache.content.size(_OBJ_UDF)}")
    # warm_stats is a diagnostic (equal-cost predicates leave little for a
    # stats-only warm start to win on this trace); the gated claim is the
    # combined warm-start + content-hash-cache win:
    assert t_cold / t_warm >= REPEAT_SPEEDUP_GATE, (
        f"repeated-query speedup {t_cold / t_warm:.2f}x "
        f"< gate {REPEAT_SPEEDUP_GATE}x (cold {t_cold:.3f}s, "
        f"warm {t_warm:.3f}s)"
    )


_OBJ_UDF = "obj"  # udf name of the first trace predicate (for reporting)


def main_repeat() -> None:
    """CI smoke entry: just the repeated-query cross-reuse trace."""
    repeated_query_trace()


def main() -> None:
    t_base = run(FixedOrder(), use_cache=True, warmup=False)
    t_cost = run(CostDriven(), use_cache=True)
    t_reuse = run(ReuseAware(), use_cache=True)
    record("uc2/baseline_cached", t_base * 1e6, f"sim_makespan_s={t_base:.3f}")
    record("uc2/cost_driven", t_cost * 1e6, f"sim_makespan_s={t_cost:.3f}")
    record("uc2/reuse_aware", t_reuse * 1e6, f"sim_makespan_s={t_reuse:.3f}")
    record("uc2/reuse_vs_baseline", 0.0, f"{t_base/t_reuse:.2f}x")
    record("uc2/reuse_vs_cost", 0.0, f"{t_cost/t_reuse:.2f}x")
    assert t_reuse < t_base, (t_reuse, t_base)
    assert t_reuse < t_cost, (t_reuse, t_cost)

    # Fig 9 analogue: reuse-aware estimated cost per segment
    obj, hat, _ = make_preds()
    cache = ReuseCache()
    prime_cache(cache, obj, hat)
    ra = ReuseAware()
    from repro.core.stats import StatsBoard

    sb = StatsBoard(["obj", "hat"])
    sb["obj"].cost_per_row.update(OBJ_COST)
    sb["hat"].cost_per_row.update(HAT_COST)
    sb["obj"].batches = sb["hat"].batches = 1
    for seg in range(14):
        rid = np.arange(seg * SEG, (seg + 1) * SEG)
        b = make_batch({"rid": rid}, rid)
        eo = ra.est_cost(b, obj, sb, cache)
        eh = ra.est_cost(b, hat, sb, cache)
        record(f"uc2/fig9/segment{seg:02d}", 0.0,
               f"est_obj={eo*1e3:.2f}ms;est_hat={eh*1e3:.2f}ms;"
               f"routes_to={'obj' if eo <= eh else 'hat'}")

    repeated_query_trace()


if __name__ == "__main__":
    main()
