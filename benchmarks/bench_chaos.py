"""Chaos benchmark: transient launch faults under ``on_fault="retry"``.

The fault-tolerance claim (core/faults.py) is that a flaky predicate is a
RUNTIME condition the executor absorbs, not a query abort: retried
launches must converge to the exact fault-free answer, and the retry
machinery must cost bounded makespan, not a multiple of it.

Workload: sleep predicates (fixed + marginal launch cost, the same
GIL-releasing accelerator stand-ins as bench_coalescing) filtering by
coprime moduli, so the planted ground truth is analytic.  Three runs:

  fault_free  — ``on_fault="fail_fast"`` and no injection: the baseline
                timing AND the reference row-id multiset.
  faulty      — identical stream with a seeded ``FaultPlan`` injecting
                ~FAULT_PROBABILITY transient launch failures per attempt
                on every predicate, under ``on_fault="retry"``.
  quarantine  — a predicate failing EVERY launch (probability=1.0) beside
                healthy siblings, with warmup on: the run must terminate
                with the failing predicate quarantined and every batch
                carrying its conservative pass-through flag.

Correctness gates (ENFORCED, both modes): the faulty run completes the
EXACT row-id multiset of the fault-free run (which itself matches the
analytic ground truth) with zero pass-through verdicts — transient faults
are invisible to results; the quarantine run terminates with the failing
predicate quarantined and the healthy predicates' exact multiset.

Timing gate (ENFORCED, both modes): faulty makespan <= MAX_OVERHEAD x
fault-free.  Sleep-dominated predicates make this core-count independent
— the overhead is the injected retries' backoff + relaunch time, not a
scheduling artifact — so it survives a loaded 1-core CI runner.

Modes (env CHAOS_BENCH_MODE or ``main(mode=...)``):
  smoke — CI-sized (~40 batches); regenerates BENCH_chaos.json so the
          artifact always matches the harness.
  full  — the committed-artifact run (96 batches).

The artifact is written by THIS harness (never hand-edited): repo-root
BENCH_chaos.json.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import List, Optional

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, FaultConfig, FaultPlan, Predicate, UDF,
    make_batch,
)

ROWS_PER_BATCH = 8
CENTRAL_CAPACITY = 128

# sleep predicates: per-launch fixed + per-row marginal (seconds), one
# coprime modulus each so the surviving set is analytic
MODULI = (3, 5, 7, 11, 13)
SLEEP_FIXED_S = 0.002
SLEEP_MARGINAL_S = 2e-5

FAULT_PROBABILITY = 0.05   # ~5% transient failures per launch attempt
FAULT_SEED = 7
RETRY_CONFIG = FaultConfig(
    mode="retry", max_attempts=6, backoff_base_s=0.002, backoff_cap_s=0.01,
    jitter=0.25, seed=FAULT_SEED, quarantine_after=12,
)
MAX_OVERHEAD = 1.5         # faulty makespan <= 1.5x fault-free (enforced)

FULL_BATCHES, SMOKE_BATCHES = 96, 40

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "BENCH_chaos.json")


def build_predicates(moduli=MODULI) -> List[Predicate]:
    """Fresh predicates per run: UDF state (out_spec, degraded) is
    per-instance, and a fair timing comparison starts cold."""
    preds = []
    for i, m in enumerate(moduli):
        def fn(cols, _m=m):
            time.sleep(SLEEP_FIXED_S + SLEEP_MARGINAL_S * len(cols["rid"]))
            return cols["rid"] % _m != 0

        udf = UDF(name=f"mod{m}", fn=fn, columns=("rid",), bucket=False,
                  resource=f"r{i}",
                  cost_model=lambda r: SLEEP_FIXED_S + SLEEP_MARGINAL_S * r)
        preds.append(Predicate(name=f"mod{m}", udf=udf,
                               compare=lambda out: out.astype(bool)))
    return preds


def build_batches(n: int):
    return [
        make_batch({"rid": np.arange(b * ROWS_PER_BATCH,
                                     (b + 1) * ROWS_PER_BATCH)},
                   row_ids=np.arange(b * ROWS_PER_BATCH,
                                     (b + 1) * ROWS_PER_BATCH))
        for b in range(n)
    ]


def expected_row_ids(n_rows: int, moduli=MODULI):
    rid = np.arange(n_rows)
    mask = np.ones(n_rows, bool)
    for m in moduli:
        mask &= rid % m != 0
    return collections.Counter(rid[mask].tolist())


def run_once(n_batches: int, *, on_fault, fault_plan=None):
    preds = build_predicates()
    ex = AQPExecutor(
        preds,
        policy=CostDriven(),
        max_workers=1,
        warmup=False,
        central_capacity=CENTRAL_CAPACITY,
        on_fault=on_fault,
        fault_plan=fault_plan,
    )
    t0 = time.perf_counter()
    done = ex.collect(iter(build_batches(n_batches)))
    elapsed = time.perf_counter() - t0
    row_ids = collections.Counter()
    passthrough = 0
    for b in done:
        row_ids.update(b.row_ids.tolist())
        passthrough += len(b.passthrough)
    faults = ex.stats_snapshot()["_faults"]
    return {
        "elapsed_s": elapsed,
        "batches_per_s": n_batches / elapsed,
        "injected": 0 if fault_plan is None else fault_plan.injected,
        "failures": sum(f["failures"] for f in faults.values()),
        "retries": sum(f["retries"] for f in faults.values()),
        "passthrough_flags": passthrough,
        "quarantined": sorted(n for n, f in faults.items()
                              if f["quarantined"]),
    }, row_ids


def run_quarantine(n_batches: int):
    """A predicate failing every launch must not take the query down: it
    quarantines, every batch carries its pass-through flag, and the
    healthy predicates' exact multiset survives — with warmup ON, so the
    never-measured failing predicate exercises the warmup-gate exemption."""
    preds = build_predicates(moduli=MODULI[:2])
    plan = FaultPlan(seed=FAULT_SEED).fail(preds[0].name, probability=1.0)
    cfg = FaultConfig(mode="retry", max_attempts=2, quarantine_after=4,
                      backoff_base_s=0.001, backoff_cap_s=0.004, jitter=0.0,
                      seed=FAULT_SEED)
    ex = AQPExecutor(preds, policy=CostDriven(), max_workers=1, warmup=True,
                     central_capacity=CENTRAL_CAPACITY, on_fault=cfg,
                     fault_plan=plan)
    t0 = time.perf_counter()
    done = ex.collect(iter(build_batches(n_batches)))
    elapsed = time.perf_counter() - t0

    n_rows = n_batches * ROWS_PER_BATCH
    # preds[0] passes through (all rows kept, flagged); preds[1] filters
    expected = expected_row_ids(n_rows, moduli=MODULI[1:2])
    got = collections.Counter(int(i) for b in done for i in b.row_ids)
    assert got == expected, (
        f"quarantine run lost/duplicated rows: extra={got - expected} "
        f"missing={expected - got}")
    flagged = sum(preds[0].name in b.passthrough for b in done)
    assert flagged == len(done), (
        f"only {flagged}/{len(done)} outputs carry the pass-through flag")
    f = ex.stats_snapshot()["_faults"][preds[0].name]
    assert f["quarantined"], "failing predicate never quarantined"
    return {
        "elapsed_s": elapsed,
        "batches": len(done),
        "quarantined": True,
        "skipped_routes": f["skipped_routes"],
        "quarantined_batches": f["quarantined_batches"],
    }


def main(mode: Optional[str] = None) -> dict:
    mode = mode or os.environ.get("CHAOS_BENCH_MODE", "smoke")
    assert mode in ("smoke", "full"), mode
    n = FULL_BATCHES if mode == "full" else SMOKE_BATCHES
    n_rows = n * ROWS_PER_BATCH
    expected = expected_row_ids(n_rows)

    base, base_rows = run_once(n, on_fault="fail_fast")
    assert base_rows == expected, (
        f"fault-free run diverged from ground truth: "
        f"extra={base_rows - expected} missing={expected - base_rows}")
    record("chaos/fault_free", base["elapsed_s"] / n * 1e6,
           f"bps={base['batches_per_s']:.1f}")

    plan = FaultPlan(seed=FAULT_SEED)
    for m in MODULI:
        plan.fail(f"mod{m}", probability=FAULT_PROBABILITY)
    faulty, faulty_rows = run_once(n, on_fault=RETRY_CONFIG, fault_plan=plan)
    # THE gate: transient faults are invisible to results — exact row-id
    # multiset equality with the fault-free run, zero pass-through verdicts
    assert faulty_rows == base_rows, (
        f"faulty run diverged from fault-free: "
        f"extra={faulty_rows - base_rows} missing={base_rows - faulty_rows}")
    assert faulty["passthrough_flags"] == 0, (
        f"transient faults escalated to {faulty['passthrough_flags']} "
        f"pass-through verdicts (retry budget too small?)")
    assert faulty["injected"] > 0, "fault plan injected nothing"
    assert faulty["quarantined"] == [], faulty["quarantined"]
    overhead = faulty["elapsed_s"] / base["elapsed_s"]
    faulty["overhead_x"] = overhead
    record("chaos/faulty", faulty["elapsed_s"] / n * 1e6,
           f"bps={faulty['batches_per_s']:.1f};injected={faulty['injected']};"
           f"retries={faulty['retries']};overhead={overhead:.2f}x")

    quarantine = run_quarantine(max(12, n // 2))
    record("chaos/quarantine", 0.0,
           f"skips={quarantine['skipped_routes']};"
           f"qbatches={quarantine['quarantined_batches']}")

    artifact = {
        "benchmark": "chaos",
        "mode": mode,
        "n_preds": len(MODULI),
        "n_batches": n,
        "rows_per_batch": ROWS_PER_BATCH,
        "fault_probability": FAULT_PROBABILITY,
        "fault_seed": FAULT_SEED,
        "cpu_count": os.cpu_count() or 1,
        "row_id_multiset_match": True,  # asserted above for every run
        "runs": {
            "fault_free": base,
            "faulty": faulty,
            "quarantine": quarantine,
        },
        "gates": {
            "max_overhead": MAX_OVERHEAD,
            "enforced": True,
            "reason": "sleep-dominated workload: retry overhead is "
                      "backoff + relaunch time, core-count independent",
        },
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    record("chaos/artifact", 0.0, os.path.normpath(ARTIFACT))

    assert overhead <= MAX_OVERHEAD, (
        f"faulty makespan {overhead:.2f}x fault-free exceeds the "
        f"{MAX_OVERHEAD}x gate")
    return artifact


if __name__ == "__main__":
    main(mode=os.environ.get("CHAOS_BENCH_MODE"))
