"""Adaptive micro-batch coalescing: fused-launch throughput vs per-batch.

The workload is the regime the coalescing layer targets: a stream of tiny
(8-row) batches through predicates whose per-invocation cost is dominated
by a FIXED launch term —

* SLEEP predicates: ``fixed + marginal*rows`` sleeps standing in for a
  GIL-releasing accelerator dispatch, with the matching affine cost model
  (the planner's roofline-style seed);
* a SATURATED predicate: pure per-row cost, ~zero launch overhead — the
  adaptive planner must be decline-dominant on it (asserted);
* DETECTOR predicates: real interpret-mode Pallas HSV-kernel launches
  (udfs/synthetic.planted_detector) whose per-launch interpret overhead is
  ~flat in rows — the honest analogue of a cold dispatch path, and exactly
  what fusing amortizes.

Three executor runs — ``coalesce=off``, ``coalesce="fixed"`` (k-batch
ablation), ``coalesce="adaptive"`` — over the identical batch stream.

Correctness gates, BOTH modes: every run completes the exact same row-id
MULTISET as the naive planted ground truth (fusing is invisible to
results); the adaptive run fused every sleep/detector predicate and was
decline-dominant on the saturated one.

Timing gate, BOTH modes: adaptive >= MIN_ADAPTIVE_SPEEDUP x batches/s over
off. Unlike the sharded-routing bench this is core-count independent —
the speedup comes from paying the fixed launch term once per fused group
instead of once per batch, so it survives a loaded 1-core runner.

Modes (env COALESCE_BENCH_MODE or ``main(mode=...)``):
  smoke — CI-sized (1 detector, 24 batches, ~10 s); regenerates
          BENCH_coalescing.json so the artifact always matches the harness.
  full  — the committed-artifact run (2 detectors, 64 batches).

The artifact is written by THIS harness (never hand-edited): repo-root
BENCH_coalescing.json, one entry per coalesce mode plus host metadata.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import List, Optional

import numpy as np

from benchmarks.harness import record
from repro.core import AQPExecutor, CostDriven, Predicate, UDF, make_batch
from repro.udfs.synthetic import planted_detector

ROWS_PER_BATCH = 8
WORK_DIM = 32            # detector crop edge (rows reshape to 32x32x3)
CENTRAL_CAPACITY = 128   # deep watermark: keep the pipeline saturated
COALESCE_MODES = (None, "fixed", "adaptive")

# sleep predicates: per-launch fixed + per-row marginal (seconds)
SLEEP_FIXED_S = (0.002, 0.0025, 0.003, 0.0035)
SLEEP_MARGINAL_S = 2e-5
# saturated predicate: pure per-row cost, nothing to amortize
SATURATED_PER_ROW_S = 6e-5

FULL_BATCHES, FULL_DETECTORS = 64, 2
SMOKE_BATCHES, SMOKE_DETECTORS = 24, 1

MIN_ADAPTIVE_SPEEDUP = 1.5  # enforced in BOTH modes (core-count independent)

ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_coalescing.json")


def build_predicates(n_detectors: int, planted: List[np.ndarray]) -> List[Predicate]:
    preds = []
    for i, fixed in enumerate(SLEEP_FIXED_S):
        def fn(cols, _f=fixed):
            time.sleep(_f + SLEEP_MARGINAL_S * len(cols["rid"]))
            return np.ones(len(cols["rid"]), dtype=bool)

        udf = UDF(name=f"sleep{i}", fn=fn, columns=("rid",), bucket=False,
                  resource=f"r{i}",
                  cost_model=lambda r, _f=fixed: _f + SLEEP_MARGINAL_S * r)
        preds.append(Predicate(name=f"sleep{i}", udf=udf,
                               compare=lambda out: out.astype(bool)))

    def sat_fn(cols):
        # busy-wait, not time.sleep: sleep's ~0.5 ms timer slack would BE a
        # fixed launch term, and the online fit would (correctly) find it —
        # a predicate whose wall cost is honestly per-row must spin
        t_end = time.perf_counter() + SATURATED_PER_ROW_S * len(cols["rid"])
        while time.perf_counter() < t_end:
            pass
        return cols["rid"] % 7 != 0

    sat = UDF(name="saturated", fn=sat_fn, columns=("rid",), bucket=False,
              resource="rsat",
              cost_model=lambda r: SATURATED_PER_ROW_S * r)  # fixed == 0
    preds.append(Predicate(name="saturated", udf=sat,
                           compare=lambda out: out.astype(bool)))

    for d in range(n_detectors):
        preds.append(planted_detector(
            f"detector{d}", planted[d], work_dim=WORK_DIM,
            resource=f"tpu:{d}"))
    return preds


def build_batches(n: int, rng: np.random.Generator):
    out = []
    for b in range(n):
        rid = np.arange(b * ROWS_PER_BATCH, (b + 1) * ROWS_PER_BATCH)
        frame = rng.random(
            (ROWS_PER_BATCH, WORK_DIM, WORK_DIM, 3), dtype=np.float32)
        out.append(make_batch({"rid": rid, "frame": frame}, row_ids=rid))
    return out


def expected_row_ids(n_rows: int, planted: List[np.ndarray]):
    rid = np.arange(n_rows)
    mask = rid % 7 != 0  # the saturated predicate; sleeps pass all rows
    for p in planted:
        mask &= p[:n_rows]
    return collections.Counter(rid[mask].tolist())


def run_once(coalesce, preds, batches):
    ex = AQPExecutor(
        preds,
        policy=CostDriven(),
        max_workers=1,          # fixed stage capacity: queues back up, fuse
        warmup=False,
        coalesce=coalesce,
        central_capacity=CENTRAL_CAPACITY,
    )
    t0 = time.perf_counter()
    done = ex.collect(iter(batches))
    elapsed = time.perf_counter() - t0
    row_ids = collections.Counter()
    for b in done:
        row_ids.update(b.row_ids.tolist())
    snap = ex.stats_snapshot()
    per_pred = {
        p.name: {
            "launches": snap[p.name]["launches"],
            "fused_launches": snap[p.name]["fused_launches"],
            "fused_batches": snap[p.name]["fused_batches"],
        }
        for p in preds
    }
    return {
        "coalesce": "off" if coalesce is None else coalesce,
        "batches": len(done),
        "elapsed_s": elapsed,
        "batches_per_s": len(batches) / elapsed,
        "launches": sum(v["launches"] for v in per_pred.values()),
        "fused_launches": sum(v["fused_launches"] for v in per_pred.values()),
        "predicates": per_pred,
        "planner": snap.get("_coalesce"),
    }, row_ids


def main(mode: Optional[str] = None) -> dict:
    mode = mode or os.environ.get("COALESCE_BENCH_MODE", "smoke")
    assert mode in ("smoke", "full"), mode
    n = FULL_BATCHES if mode == "full" else SMOKE_BATCHES
    n_detectors = FULL_DETECTORS if mode == "full" else SMOKE_DETECTORS

    rng = np.random.default_rng(7)
    n_rows = n * ROWS_PER_BATCH
    planted = [rng.random(n_rows) < 0.8 for _ in range(n_detectors)]
    preds = build_predicates(n_detectors, planted)
    batches = build_batches(n, rng)
    expected = expected_row_ids(n_rows, planted)

    runs, off_bps = [], None
    for coalesce in COALESCE_MODES:
        result, row_ids = run_once(coalesce, preds, batches)
        # correctness gate, BOTH modes, EVERY coalesce mode: fusing is
        # invisible to results — the exact planted row-id multiset
        assert row_ids == expected, (
            f"coalesce={result['coalesce']} lost/duplicated rows: "
            f"extra={row_ids - expected} missing={expected - row_ids}"
        )
        if off_bps is None:
            off_bps = result["batches_per_s"]
        else:
            result["speedup"] = result["batches_per_s"] / off_bps
        runs.append(result)
        record(
            f"coalescing/{result['coalesce']}",
            result["elapsed_s"] / n * 1e6,
            f"bps={result['batches_per_s']:.1f};launches={result['launches']}"
            + (f";speedup={result['speedup']:.2f}x" if "speedup" in result
               else ""),
        )

    adaptive = runs[-1]
    # the adaptive policy's decline contract: launch-dominated predicates
    # fused; the saturated predicate is DECLINE-DOMINANT. (Not zero-fused:
    # once upstream filtering gives its arrivals row-count spread, the
    # online fit measures the genuine ~0.1 ms sleep/call overhead and may
    # occasionally judge a fuse worthwhile — that is the planner reading
    # reality, and reality has no perfectly-fixed-cost-free predicate.)
    sat_plan = adaptive["planner"]["saturated"]
    assert sat_plan["declines"] > sat_plan["plans"], (
        f"adaptive planned the saturated predicate more often than it "
        f"declined it: {sat_plan}")
    for name in list(adaptive["predicates"]):
        if name != "saturated":
            assert adaptive["predicates"][name]["fused_launches"] > 0, (
                f"adaptive never fused {name}")
    assert adaptive["launches"] < runs[0]["launches"]

    artifact = {
        "benchmark": "coalescing",
        "mode": mode,
        "n_preds": len(preds),
        "n_detectors": n_detectors,
        "n_batches": n,
        "rows_per_batch": ROWS_PER_BATCH,
        "work_dim": WORK_DIM,
        "cpu_count": os.cpu_count() or 1,
        "row_id_multiset_match": True,  # asserted above for every run
        "runs": runs,
        "gates": {
            "adaptive_min_speedup": MIN_ADAPTIVE_SPEEDUP,
            "enforced": True,
            "reason": "launch-amortization speedup is core-count "
                      "independent: enforced in both modes",
        },
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    record("coalescing/artifact", 0.0, os.path.normpath(ARTIFACT))

    assert adaptive["speedup"] >= MIN_ADAPTIVE_SPEEDUP, (
        f"adaptive coalescing {adaptive['speedup']:.2f}x below the "
        f"{MIN_ADAPTIVE_SPEEDUP}x gate over coalesce=off"
    )
    return artifact


if __name__ == "__main__":
    main(mode=os.environ.get("COALESCE_BENCH_MODE"))
