"""Shared benchmark utilities: timing, CSV rows, Hydro system variants."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

ROWS: List[Dict] = []


def record(name: str, us_per_call: float, derived: str = "") -> Dict:
    row = {"name": name, "us_per_call": us_per_call, "derived": derived}
    ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")
    return row


def timeit(fn: Callable, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def csv_header() -> None:
    print("name,us_per_call,derived")
