"""UC4 (paper Fig. 14): data-aware load balancing for an LLM predicate.

Reviews with heavy-tailed lengths; query = LLM(review)=food AND rating<=1
(rating pushed down by the rule optimizer upstream). Three setups, 10
shuffled runs each (the paper reports 10 runs for the same reason —
pipeline queues randomize order):

  +eddy (1 worker) | +eddy+laminar round-robin | +eddy+laminar data-aware

The simulated LLM cost is proportional to TEXT LENGTH (the paper's
workload-imbalance driver: longer reviews take longer); the data-aware
policy balances on the same proxy (input size, §5.3). Expected: data-aware
< round-robin < eddy-only, with ~1.2-1.5x data-aware wins (paper: 1.46x).
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, DataAware, Predicate, RoundRobin, SimClock, UDF,
    make_batch,
)
from repro.data.text import make_reviews

TOKENS_PER_SEC = 2000.0  # simulated LLM throughput


def make_llm_pred():
    def fn(d):
        return (d["tokens"] > 0).sum(axis=1) % 2 == 0  # placeholder verdict

    def cost_model(rows, data):  # data-aware: seconds ~ tokens in the batch
        return float((data["tokens"] > 0).sum()) / TOKENS_PER_SEC

    udf = UDF(
        "LLM", fn=fn, columns=("tokens",), resource="cpu0", bucket=False,
        cost_model=cost_model,
        proxy_cost=lambda d: float((d["tokens"] > 0).sum()),  # text length
    )
    return Predicate("llm", udf, compare=lambda o: o.astype(bool))


def run_sim(policy_factory, reviews, *, workers, seed):
    rng = np.random.default_rng(seed)
    shuffled = [reviews[i] for i in rng.permutation(len(reviews))]
    batches = [
        make_batch({"tokens": r.tokens[None, :]}, np.array([r.rid]))
        for r in shuffled
    ]
    pred = make_llm_pred()
    clk = SimClock()
    ex = AQPExecutor([pred], policy=CostDriven(), clock=clk,
                     laminar_policy_factory=policy_factory,
                     max_workers=workers, warmup=False)
    n = sum(b.rows for b in ex.run(iter(batches)))
    assert n > 0
    return clk.makespan


def main() -> None:
    reviews = make_reviews(600)
    times = {}
    for name, factory, workers in (
        ("eddy_only", RoundRobin, 1),
        ("laminar_round_robin", RoundRobin, 4),
        ("laminar_data_aware", DataAware, 4),
    ):
        runs = [run_sim(factory, reviews, workers=workers, seed=s)
                for s in range(10)]
        med = float(np.median(runs))
        times[name] = med
        record(f"uc4/{name}", med * 1e6,
               f"sim_median_s={med:.3f};p10={np.percentile(runs,10):.3f};"
               f"p90={np.percentile(runs,90):.3f};runs=10")
    rr, da = times["laminar_round_robin"], times["laminar_data_aware"]
    base = times["eddy_only"]
    record("uc4/data_aware_vs_rr", 0.0, f"{rr/da:.2f}x")
    record("uc4/laminar_vs_eddy", 0.0, f"{base/rr:.2f}x")
    assert da < rr, (da, rr)       # paper: data-aware beats round-robin
    assert rr < base, (rr, base)   # laminar scaling helps


if __name__ == "__main__":
    main()
