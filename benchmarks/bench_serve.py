"""Multi-tenant serving benchmark: QueryService vs serialized executors.

The QueryService claim (launch/serve.py) is that N concurrent queries
tenanting ONE shared arbiter finish with higher goodput than the same
queries run one-executor-at-a-time: sleep-dominated ML predicates leave
the pipeline idle most of the wall time, and a rival tenant's work fills
those gaps — the latency a query pays for sharing is far smaller than the
queueing delay it would pay waiting for a serial slot.

Workload: an OPEN-LOOP arrival schedule (fixed inter-arrival gap, arrivals
don't wait for completions) of queries, each with its OWN predicate
(distinct names — no serialization conflicts) filtering by a coprime
modulus, so every query's surviving row-id multiset is analytic.  Sleep
predicates (fixed + marginal launch cost, the GIL-releasing accelerator
stand-ins of bench_chaos/bench_coalescing) make the speedup come from
OVERLAP, not core count — the gate survives a loaded 1-core CI runner.

Two runs over the identical schedule:

  serialized — one executor at a time, FIFO in arrival order: query i
               starts at max(arrival_i, finish_{i-1}) (the pre-service
               behavior for concurrent submissions).
  service    — QueryService(max_concurrent=MAX_CONCURRENT): admission,
               priority dispatch, shared-arbiter tenancy, live-prior
               folding.

Metrics: per-query latency (finish - arrival) p50/p99, goodput =
deadline-met queries / makespan.

Gates (ENFORCED, both modes):
  * every query's EXACT analytic row-id multiset, in both runs;
  * zero cross-query statistics leakage — each service report's board
    holds only that query's own predicate;
  * goodput: service >= MIN_GOODPUT_SPEEDUP x serialized.

Modes (env SERVE_BENCH_MODE or ``main(mode=...)``):
  smoke — CI-sized (fewer queries/batches); regenerates BENCH_serve.json
          so the artifact always matches the harness.
  full  — the committed-artifact run.

The artifact is written by THIS harness (never hand-edited): repo-root
BENCH_serve.json.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import List, Optional

import numpy as np

from benchmarks.harness import record
from repro.core import AQPExecutor, Predicate, UDF, make_batch
from repro.launch.serve import QueryService

ROWS_PER_BATCH = 8
SLEEP_FIXED_S = 0.002
SLEEP_MARGINAL_S = 2e-5

MODULI = (3, 5, 7, 11, 13, 17, 19, 23)   # one coprime modulus per query
INTERARRIVAL_S = 0.01                    # open-loop: arrivals never wait
DEADLINE_S = 30.0                        # generous: misses mean pathology
MAX_CONCURRENT = 4
MIN_GOODPUT_SPEEDUP = 1.2                # service/serialized gate (enforced)

FULL_QUERIES, FULL_BATCHES = 8, 16
SMOKE_QUERIES, SMOKE_BATCHES = 5, 10

_EXEC_KW = dict(max_workers=1, warmup=False, central_capacity=128)

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def build_predicate(qi: int, m: int) -> Predicate:
    """Per-query sleep predicate ``q{qi}m{m}``: keeps rid % m != 0."""

    def fn(cols, _m=m):
        time.sleep(SLEEP_FIXED_S + SLEEP_MARGINAL_S * len(cols["rid"]))
        return cols["rid"] % _m != 0

    name = f"q{qi}m{m}"
    udf = UDF(name=name + "_udf", fn=fn, columns=("rid",), bucket=False,
              resource=f"r{qi}",
              cost_model=lambda r: SLEEP_FIXED_S + SLEEP_MARGINAL_S * r)
    return Predicate(name=name, udf=udf, compare=lambda out: out.astype(bool))


def build_batches(qi: int, n_batches: int):
    base = qi * 100_000                     # disjoint id spaces per query
    return [
        make_batch({"rid": np.arange(base + b * ROWS_PER_BATCH,
                                     base + (b + 1) * ROWS_PER_BATCH)},
                   row_ids=np.arange(base + b * ROWS_PER_BATCH,
                                     base + (b + 1) * ROWS_PER_BATCH))
        for b in range(n_batches)
    ]


def expected_row_ids(qi: int, m: int, n_batches: int):
    rid = np.arange(qi * 100_000, qi * 100_000 + n_batches * ROWS_PER_BATCH)
    return collections.Counter(rid[rid % m != 0].tolist())


def _percentiles(latencies: List[float]):
    arr = np.asarray(latencies)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def run_serialized(n_queries: int, n_batches: int):
    """One executor at a time, FIFO in arrival order, same open-loop
    schedule: latency counts the serial queueing delay."""
    t0 = time.perf_counter()
    latencies, met = [], 0
    for qi in range(n_queries):
        arrival = qi * INTERARRIVAL_S
        now = time.perf_counter() - t0
        if now < arrival:
            time.sleep(arrival - now)       # open-loop: arrival gap only
        pred = build_predicate(qi, MODULI[qi])
        ex = AQPExecutor([pred], **_EXEC_KW)
        out = ex.collect(iter(build_batches(qi, n_batches)))
        got = collections.Counter(int(i) for b in out for i in b.row_ids)
        exp = expected_row_ids(qi, MODULI[qi], n_batches)
        assert got == exp, (
            f"serialized q{qi}: extra={got - exp} missing={exp - got}")
        lat = (time.perf_counter() - t0) - arrival
        latencies.append(lat)
        met += lat <= DEADLINE_S
    makespan = time.perf_counter() - t0
    p50, p99 = _percentiles(latencies)
    return {
        "makespan_s": makespan,
        "p50_s": p50,
        "p99_s": p99,
        "deadline_met": met,
        "goodput_qps": met / makespan,
    }


def run_service(n_queries: int, n_batches: int):
    """The same schedule through QueryService: open-loop submission (a
    submitter thread per arrival), shared arbiter, MAX_CONCURRENT tenants."""
    handles: List = [None] * n_queries
    with QueryService(max_concurrent=MAX_CONCURRENT,
                      max_pending=n_queries) as svc:
        t0 = time.perf_counter()

        def submit(qi):
            time.sleep(max(0.0, qi * INTERARRIVAL_S
                           - (time.perf_counter() - t0)))
            handles[qi] = svc.submit(
                [build_predicate(qi, MODULI[qi])],
                iter(build_batches(qi, n_batches)),
                deadline_s=DEADLINE_S, **_EXEC_KW)

        threads = [threading.Thread(target=submit, args=(qi,))
                   for qi in range(n_queries)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        reports = [handles[qi].result(timeout=120)
                   for qi in range(n_queries)]
        makespan = time.perf_counter() - t0
        counters = svc.snapshot()

    latencies, met = [], 0
    for qi, rep in enumerate(reports):
        assert rep.state == "DONE", (qi, rep.state, rep.error)
        got = collections.Counter(int(i) for i in rep.row_ids)
        exp = expected_row_ids(qi, MODULI[qi], n_batches)
        assert got == exp, (
            f"service q{qi}: extra={got - exp} missing={exp - got}")
        # zero cross-query leakage: the board profiled ONLY its own predicate
        assert rep.board_predicates == (f"q{qi}m{MODULI[qi]}",), (
            f"service q{qi} board leaked rivals: {rep.board_predicates}")
        latencies.append(rep.queue_time_s + rep.eval_time_s)
        met += bool(rep.deadline_met)
    p50, p99 = _percentiles(latencies)
    return {
        "makespan_s": makespan,
        "p50_s": p50,
        "p99_s": p99,
        "deadline_met": met,
        "goodput_qps": met / makespan,
        "queue_p99_s": float(np.percentile(
            [r.queue_time_s for r in reports], 99)),
        "cross_query_handoffs": counters["arbiter"]["cross_query_handoffs"],
        "rebalances": counters["arbiter"]["rebalances"],
    }


def main(mode: Optional[str] = None) -> dict:
    mode = mode or os.environ.get("SERVE_BENCH_MODE", "smoke")
    assert mode in ("smoke", "full"), mode
    n_queries, n_batches = ((FULL_QUERIES, FULL_BATCHES) if mode == "full"
                            else (SMOKE_QUERIES, SMOKE_BATCHES))

    serial = run_serialized(n_queries, n_batches)
    record("serve/serialized", serial["makespan_s"] / n_queries * 1e6,
           f"p50={serial['p50_s'] * 1e3:.1f}ms;"
           f"p99={serial['p99_s'] * 1e3:.1f}ms;"
           f"goodput={serial['goodput_qps']:.1f}qps")

    service = run_service(n_queries, n_batches)
    speedup = service["goodput_qps"] / serial["goodput_qps"]
    service["goodput_speedup_x"] = speedup
    record("serve/service", service["makespan_s"] / n_queries * 1e6,
           f"p50={service['p50_s'] * 1e3:.1f}ms;"
           f"p99={service['p99_s'] * 1e3:.1f}ms;"
           f"goodput={service['goodput_qps']:.1f}qps;"
           f"speedup={speedup:.2f}x")

    # THE gate: multi-tenant goodput beats one-executor-at-a-time
    assert service["deadline_met"] == n_queries, (
        f"service missed {n_queries - service['deadline_met']} deadlines")
    assert speedup >= MIN_GOODPUT_SPEEDUP, (
        f"service goodput speedup {speedup:.2f}x < "
        f"{MIN_GOODPUT_SPEEDUP}x over serialized baseline")

    artifact = {
        "benchmark": "serve",
        "mode": mode,
        "n_queries": n_queries,
        "n_batches": n_batches,
        "rows_per_batch": ROWS_PER_BATCH,
        "interarrival_s": INTERARRIVAL_S,
        "deadline_s": DEADLINE_S,
        "max_concurrent": MAX_CONCURRENT,
        "min_goodput_speedup": MIN_GOODPUT_SPEEDUP,
        "serialized": serial,
        "service": service,
        "gates": {
            "exact_multisets": True,
            "no_board_leakage": True,
            "goodput_speedup_ok": speedup >= MIN_GOODPUT_SPEEDUP,
        },
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=2, sort_keys=True)
        f.write("\n")
    record("serve/artifact", 0.0, f"mode={mode};speedup={speedup:.2f}x")
    return artifact


if __name__ == "__main__":
    main()
