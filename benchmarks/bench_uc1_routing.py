"""UC1 (paper Fig. 5 + Table 1 / Fig. 6): routing-policy comparison.

Five system variants over the lost-dog query on synthetic video, on the
deterministic simulated clock (same predicate cost/selectivity structure as
the paper: breed ~30ms/row on the accelerator, color ~2ms/row on CPU):

  no-reordering | best-reordering (oracle static) | eddy cost-driven |
  eddy score-driven | eddy selectivity-driven

Paper's claims to reproduce: all eddy variants beat no-reordering;
cost ~= score >= selectivity; cost ~= best-reordering (Fig 5).
--case 1|2 reruns the Table 1 predicate regimes (Fig 6).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, ScoreDriven, SelectivityDriven, SimClock,
    make_batch,
)
from repro.core.policies import EddyPolicy
from repro.udfs import planted_predicate

BREED_COST = 0.030   # s/row — paper: 35.11ms (case 1: 29.5, case 2: 28.3)
COLOR_COST = 0.002   # s/row — paper: 1.98ms


class FixedOrder(EddyPolicy):
    name = "fixed"

    def __init__(self, order):
        self.order = list(order)

    def rank(self, batch, preds, stats, cache):
        pos = {n: i for i, n in enumerate(self.order)}
        return sorted(preds, key=lambda p: pos[p.name])


def build(case: int, n_rows: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    if case == 1:   # Table 1 case 1: breed sel 0.060, color sel 0.374
        sel_breed, sel_color = 0.060, 0.374
    else:           # Table 1 case 2: breed sel 0.227, color sel 0.056
        sel_breed, sel_color = 0.227, 0.056
    breed_pass = set(rng.choice(n_rows, int(n_rows * sel_breed), replace=False).tolist())
    color_pass = set(rng.choice(n_rows, int(n_rows * sel_color), replace=False).tolist())

    breed = planted_predicate("breed", breed_pass,
                              cost_per_row=BREED_COST, resource="tpu:0")
    color = planted_predicate("color", color_pass,
                              cost_per_row=COLOR_COST, resource="cpu")
    batches = [
        make_batch({"rid": np.arange(i, min(i + 10, n_rows))},
                   np.arange(i, min(i + 10, n_rows)))
        for i in range(0, n_rows, 10)
    ]
    expect = breed_pass & color_pass
    return breed, color, batches, expect, (sel_breed, sel_color)


def run_variant(policy, preds, batches, expect, *, warmup=True, seed_stats=None):
    clk = SimClock()
    ex = AQPExecutor(list(preds), policy=policy, clock=clk, max_workers=1,
                     warmup=warmup)
    if seed_stats:
        for name, cost, sel in seed_stats:
            st = ex.stats[name]
            st.cost_per_row.update(cost)
            st.tickets, st.wins, st.batches = 1000, int(1000 * (1 - sel)), 1
    got = {int(i) for b in ex.run(iter(batches)) for i in b.row_ids}
    assert got == expect, (policy, len(got), len(expect))
    return ex.makespan


def main(case: int = 0, n_rows: int = 600) -> None:
    cases = [1, 2] if case == 0 else [case]
    for c in cases:
        breed, color, batches, expect, (sb, sc) = build(c, n_rows)
        seed = [("breed", BREED_COST, sb), ("color", COLOR_COST, sc)]

        variants = {
            "no_reordering": lambda: run_variant(
                FixedOrder(["breed", "color"]), [breed, color],
                build(c, n_rows)[2], expect, warmup=False, seed_stats=seed),
            "best_reordering": lambda: run_variant(
                FixedOrder(["color", "breed"]), [breed, color],
                build(c, n_rows)[2], expect, warmup=False, seed_stats=seed),
            "eddy_cost": lambda: run_variant(
                CostDriven(), [breed, color], build(c, n_rows)[2], expect),
            "eddy_score": lambda: run_variant(
                ScoreDriven(), [breed, color], build(c, n_rows)[2], expect),
            "eddy_selectivity": lambda: run_variant(
                SelectivityDriven(), [breed, color], build(c, n_rows)[2], expect),
        }
        times = {}
        for name, fn in variants.items():
            times[name] = fn()
            record(f"uc1_case{c}/{name}", times[name] * 1e6,
                   f"sim_makespan_s={times[name]:.3f}")
        base = times["no_reordering"]
        for name in ("eddy_cost", "eddy_score", "eddy_selectivity"):
            record(f"uc1_case{c}/{name}_speedup", 0.0,
                   f"{base / times[name]:.2f}x_vs_no_reordering")
        # paper-fidelity checks (Fig 5 orderings)
        assert times["eddy_cost"] < base
        assert times["eddy_cost"] <= times["eddy_selectivity"] * 1.05
        assert times["eddy_cost"] <= times["best_reordering"] * 1.25


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--case", type=int, default=0, choices=[0, 1, 2])
    ap.add_argument("--rows", type=int, default=600)
    args = ap.parse_args()
    main(args.case, args.rows)
