"""UC2-realloc (§5.2): cross-predicate worker reallocation under a
shifting-selectivity workload (the shifting-bottleneck scenario).

Warehouse query ``obj(frame) AND hat(frame)`` where both predicates share
ONE bounded DevicePool (6 slots):

  obj — person detector, 30ms/batch. Phase 1 (crowded shift) it passes
        ~every frame; phase 2 (empty warehouse) its selectivity collapses
        to zero.
  hat — hard-hat check, 90ms/batch, ~50% selectivity on crowded frames.

Cost-driven routing sends frames to the cheaper ``obj`` first, so the
BOTTLENECK shifts with obj's selectivity: in phase 1 every frame survives
obj and the expensive ``hat`` saturates (wants ~4-5 of the 6 slots); in
phase 2 obj drops everything, ``hat``'s queues drain to silence, and obj
needs the capacity instead. A static 3/3 partition (the pre-arbiter
private pools — the ``StaticPartition`` ablation) strands half the pool on
the drained predicate; the pressure-ranked arbiter retires the idle
leases once they sit past the drain threshold and hands the slots across
predicates — the paper's "dynamically allocates resources for evaluating
predicates".

Asserts: the pressure-ranked arbiter beats the static ablation on
makespan, cross-predicate handoffs actually happened, and the static
ablation performed none.

  PYTHONPATH=src:. python benchmarks/bench_uc2_realloc.py
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, DataAware, DevicePool, Predicate,
    PressureRanked, StaticPartition, UDF, make_batch,
)

N_PHASE1 = 500             # crowded frames (obj passes ~all -> hat saturated)
N_PHASE2 = 3000            # empty-warehouse frames (obj passes none)
PER = 10                   # routing-batch rows
OBJ_COST_S = 0.030         # wall seconds per obj batch evaluation
HAT_COST_S = 0.090         # wall seconds per hat batch evaluation
POOL_SLOTS = 6             # shared device capacity
DRAIN_S = 0.3              # scale-down drain threshold (pressure run)


def make_preds(seed=0):
    n = N_PHASE1 + N_PHASE2
    rng = np.random.default_rng(seed)
    obj_pass = np.zeros(n, bool)
    obj_pass[:N_PHASE1] = True                      # phase 1: crowded
    hat_pass = rng.random(n) < 0.5

    def mk(name, passes, cost):
        def fn(d):
            time.sleep(cost)                        # real wall-clock cost
            return passes[d["rid"]]

        udf = UDF(name + "_udf", fn=fn, columns=("rid",), bucket=False)
        return Predicate(name, udf, compare=lambda o: o.astype(bool))

    expect = set(np.nonzero(obj_pass & hat_pass)[0].tolist())
    return mk("obj", obj_pass, OBJ_COST_S), mk("hat", hat_pass, HAT_COST_S), expect


def batches():
    n = N_PHASE1 + N_PHASE2
    return [make_batch({"rid": np.arange(i, i + PER)}, np.arange(i, i + PER))
            for i in range(0, n, PER)]


def run(arbiter_policy, *, drain_threshold):
    obj, hat, expect = make_preds()
    ex = AQPExecutor(
        [obj, hat], policy=CostDriven(),
        laminar_policy_factory=DataAware,
        max_workers=POOL_SLOTS,
        pool=DevicePool({"cpu": POOL_SLOTS}),
        arbiter_policy=arbiter_policy, drain_threshold=drain_threshold,
    )
    t0 = time.perf_counter()
    got = {int(i) for b in ex.run(iter(batches())) for i in b.row_ids}
    makespan = time.perf_counter() - t0
    assert got == expect
    retirements = {n: l.retirements for n, l in ex.laminars.items()}
    return makespan, ex.stats_snapshot()["_arbiter"], retirements


def main() -> None:
    # static 3/3 partition = the pre-arbiter private pools (ablation)
    t_static, c_static, _ = run(
        StaticPartition(quota=POOL_SLOTS // 2), drain_threshold=None
    )
    t_press, c_press, retirements = run(
        PressureRanked(), drain_threshold=DRAIN_S
    )

    record("uc2_realloc/static_pool", t_static * 1e6,
           f"makespan_s={t_static:.3f};{c_static}")
    record("uc2_realloc/pressure_ranked", t_press * 1e6,
           f"makespan_s={t_press:.3f};{c_press}")
    record("uc2_realloc/speedup", 0.0, f"{t_static/t_press:.2f}x")
    record("uc2_realloc/retirements", 0.0, f"{retirements}")

    # §5.2 claims: reallocation must actually happen, and must win
    assert c_press["cross_pred_handoffs"] >= 1, c_press
    assert c_press["releases"] >= 1, c_press
    assert c_static["cross_pred_handoffs"] == 0, c_static
    assert t_press < t_static * 0.95, (t_press, t_static)


if __name__ == "__main__":
    main()
