"""UC3 (paper Fig. 11/12): Laminar scaling + device utilization.

Variants on the warehouse query (two GPU-bound predicates, no caches):

  baseline (static, 1 worker each) | +eddy | +eddy+laminar (1 device) |
  +eddy+laminar (2 devices) | 2 devices w/o device-alternating

The simulated clock models spatial multiplexing with a serial device
fraction (overlap of data movement/CPU/accelerator work — §5.1): workers
overlap until the device-serial fraction saturates. Paper claims:
laminar >> eddy-only (4.24x there), 2 devices scale further (1.44x), and
disabling device-aware alternation costs throughput.

Fig. 12 analogue: per-device busy fraction (utilization) is derived from
the SimClock resource horizons.
"""
from __future__ import annotations

import numpy as np

from benchmarks.harness import record
from repro.core import (
    AQPExecutor, CostDriven, DeviceAlternating, DevicePool, RoundRobin,
    SimClock, make_batch,
)
from repro.core.policies import StickyDevice
from repro.udfs import planted_predicate

N_FRAMES = 1000
OBJ_COST = 0.020
HAT_COST = 0.015
SERIAL_FRACTION = 0.15   # device-serial share -> ~6 workers saturate a device


def make_preds(seed=0):
    rng = np.random.default_rng(seed)
    person = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.5), replace=False).tolist())
    nohat = frozenset(rng.choice(N_FRAMES, int(N_FRAMES * 0.3), replace=False).tolist())

    obj = planted_predicate("obj", person, cost_per_row=OBJ_COST)
    hat = planted_predicate("hat", nohat, cost_per_row=HAT_COST)
    return obj, hat, person & nohat


def batches():
    return [
        make_batch({"rid": np.arange(i, i + 10)}, np.arange(i, i + 10))
        for i in range(0, N_FRAMES, 10)
    ]


def run(*, max_workers, devices, laminar_policy=RoundRobin, warmup=True):
    obj, hat, expect = make_preds()
    clk = SimClock()
    # explicit per-device slot inventory (arbiter era): capacity sized so
    # both predicates can reach their ceiling — the deterministic Fig. 11
    # timelines predate slot contention and must stay exact
    pool = DevicePool({dev: 2 * max_workers for dev in devices})
    ex = AQPExecutor(
        [obj, hat], policy=CostDriven(), clock=clk,
        laminar_policy_factory=laminar_policy,
        max_workers=max_workers, warmup=warmup,
        devices={"obj": devices, "hat": devices},
        serial_fraction=SERIAL_FRACTION,
        pool=pool,
    )
    got = {int(i) for b in ex.run(iter(batches())) for i in b.row_ids}
    assert got == expect
    # Fig 12 analogue: device utilization = busy seconds / makespan
    util = {
        dev: round(clk.busy_time(dev) / max(clk.makespan, 1e-9), 3)
        for dev in devices
    }
    return ex.makespan, util, ex.active_worker_counts()


def main() -> None:
    t_base, _, _ = run(max_workers=1, devices=("tpu:0",), warmup=False)
    t_eddy, u_eddy, _ = run(max_workers=1, devices=("tpu:0",))
    t_lam1, u_lam1, w1 = run(max_workers=16, devices=("tpu:0",))
    t_lam2, u_lam2, w2 = run(max_workers=16, devices=("tpu:0", "tpu:1"),
                             laminar_policy=DeviceAlternating)
    t_lam2_st, _, _ = run(max_workers=16, devices=("tpu:0", "tpu:1"),
                          laminar_policy=lambda: StickyDevice(run_length=50))

    record("uc3/baseline", t_base * 1e6, f"sim_makespan_s={t_base:.3f}")
    record("uc3/eddy", t_eddy * 1e6,
           f"sim_makespan_s={t_eddy:.3f};util={u_eddy}")
    record("uc3/eddy_laminar_1dev", t_lam1 * 1e6,
           f"sim_makespan_s={t_lam1:.3f};util={u_lam1};workers={w1}")
    record("uc3/eddy_laminar_2dev", t_lam2 * 1e6,
           f"sim_makespan_s={t_lam2:.3f};util={u_lam2};workers={w2}")
    record("uc3/eddy_laminar_2dev_no_alternate", t_lam2_st * 1e6,
           f"sim_makespan_s={t_lam2_st:.3f}")
    record("uc3/laminar_vs_eddy", 0.0, f"{t_eddy/t_lam1:.2f}x")
    record("uc3/2dev_vs_1dev", 0.0, f"{t_lam1/t_lam2:.2f}x")
    record("uc3/alternating_vs_sticky_2dev", 0.0, f"{t_lam2_st/t_lam2:.2f}x")

    # paper-fidelity: laminar >> eddy-only (GPU was ~20% utilized before);
    # 2 devices scale (paper: 1.44x); device-aware alternation beats sticky
    assert t_lam1 < t_eddy / 1.5, (t_lam1, t_eddy)
    assert u_eddy["tpu:0"] < 0.35          # Fig 12a: low util w/o laminar
    assert u_lam1["tpu:0"] > 1.5 * u_eddy["tpu:0"]  # Fig 12b: laminar lifts util
    assert t_lam2 < t_lam1, (t_lam2, t_lam1)
    assert t_lam2 <= t_lam2_st * 1.02, (t_lam2, t_lam2_st)


if __name__ == "__main__":
    main()
