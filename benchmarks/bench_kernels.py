"""Kernel micro-benchmarks (wall clock on this CPU container).

The XLA oracle path is the CPU production path (and the dry-run FLOPs
path); the Pallas kernels are TPU-targeted and validated in interpret mode
by tests (interpret-mode timing is meaningless, so it is not reported).
Also benches the TPU-native on-device cascade vs naive full evaluation —
the jitted twin of Hydro's short-circuiting.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import record, timeit
from repro.core.vectorized import cascade_filter
from repro.kernels import ops


def main() -> None:
    rng = np.random.default_rng(0)

    # flash attention (xla path)
    for b, s, h, hkv, d in ((1, 1024, 8, 2, 64), (2, 2048, 8, 2, 64)):
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
        fn = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="xla"))
        t = timeit(lambda: fn(q, k, v).block_until_ready())
        flops = 4 * b * h * s * s * d / 2  # causal
        record(f"kernel/attention_xla_b{b}_s{s}", t * 1e6,
               f"gflops={flops/t/1e9:.1f}")

    # SWA banded vs full (the sub-quadratic win)
    b, s, h, hkv, d, w = 1, 4096, 4, 1, 64, 512
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    full = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="xla"))
    swa = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, window=w, impl="xla"))
    t_full = timeit(lambda: full(q, k, v).block_until_ready())
    t_swa = timeit(lambda: swa(q, k, v).block_until_ready())
    record("kernel/swa_banded_s4096_w512", t_swa * 1e6,
           f"speedup_vs_full={t_full/t_swa:.2f}x")

    # decode attention
    b, s, h, hkv, d = 8, 4096, 8, 2, 64
    qd = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    fn = jax.jit(lambda q, k, v, l: ops.decode_attention(q, k, v, l, impl="xla"))
    t = timeit(lambda: fn(qd, kc, vc, lens).block_until_ready())
    record(f"kernel/decode_attention_b{b}_s{s}", t * 1e6,
           f"bytes={kc.nbytes*2/1e6:.0f}MB")

    # rglru
    b, s, w_ = 2, 1024, 512
    x = jnp.asarray(rng.standard_normal((b, s, w_)), jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, s, w_)), jnp.float32)
    i = jnp.asarray(rng.standard_normal((b, s, w_)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((w_,)), jnp.float32)
    fn = jax.jit(lambda x, r, i, a: ops.rglru(x, r, i, a, impl="xla")[0])
    t = timeit(lambda: fn(x, r, i, a).block_until_ready())
    record(f"kernel/rglru_s{s}_w{w_}", t * 1e6, "")

    # ssd
    b, s, h, p, g, n = 1, 1024, 8, 64, 1, 64
    xs = jnp.asarray(rng.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, g, n)) * 0.3, jnp.float32)
    fn = jax.jit(lambda *args: ops.ssd(*args, chunk=64, impl="xla")[0])
    t = timeit(lambda: fn(xs, dt, A, B, C).block_until_ready())
    record(f"kernel/ssd_s{s}_h{h}", t * 1e6, "")

    # hsv color classify
    crops = jnp.asarray(rng.uniform(0, 255, (64, 64, 64, 3)), jnp.float32)
    fn = jax.jit(lambda c: ops.hsv_color_classify(c, impl="xla")[0])
    t = timeit(lambda: fn(crops).block_until_ready())
    record("kernel/hsv_color_64x64x64", t * 1e6,
           f"rows_per_s={64/t:.0f}")

    # moe router
    logits = jnp.asarray(rng.standard_normal((65536, 128)), jnp.float32)
    fn = jax.jit(lambda l: ops.moe_topk_router(l, 2, impl="xla")[0])
    t = timeit(lambda: fn(logits).block_until_ready())
    record("kernel/moe_router_t65536_e128", t * 1e6, "")

    # on-device cascade vs naive (TPU-native short-circuit)
    x = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    big = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    cheap = lambda v: v[:, 0] > 0.8            # selective & cheap
    heavy = lambda v: ((v @ big) @ big.T).sum(-1) > 0.0

    naive = jax.jit(lambda v: cheap(v) & heavy(v))
    casc = jax.jit(lambda v: cascade_filter([cheap, heavy], v,
                                            bucket_fractions=[0.25]))
    np.testing.assert_array_equal(np.asarray(naive(x)), np.asarray(casc(x)))
    t_n = timeit(lambda: naive(x).block_until_ready())
    t_c = timeit(lambda: casc(x).block_until_ready())
    record("vectorized/cascade_vs_naive", t_c * 1e6,
           f"speedup={t_n/t_c:.2f}x")


if __name__ == "__main__":
    main()
