"""Benchmark aggregator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/harness.py), plus
a dry-run/roofline summary from results/dryrun/ when present.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only uc1
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (  # noqa: E402
    bench_chaos,
    bench_coalescing,
    bench_content_routing,
    bench_kernels,
    bench_routing_throughput,
    bench_serve,
    bench_uc1_routing,
    bench_uc1_synthetic,
    bench_uc2_reuse,
    bench_uc3_laminar,
    bench_uc4_databalance,
)
from benchmarks.harness import csv_header, record  # noqa: E402

SUITES = {
    "uc1": bench_uc1_routing.main,          # Fig 5 + Table 1 / Fig 6
    "uc1_synth": bench_uc1_synthetic.main,  # Fig 7
    "uc2": bench_uc2_reuse.main,            # Fig 8 / Fig 9 + repeated trace
    "uc2_repeat": bench_uc2_reuse.main_repeat,  # cross-query reuse smoke
    "uc3": bench_uc3_laminar.main,          # Fig 11 / Fig 12
    "uc4": bench_uc4_databalance.main,      # Fig 14
    "content": bench_content_routing.main,  # beyond-paper (§2.2 lineage)
    "kernels": bench_kernels.main,          # kernel hot spots
    "routing": bench_routing_throughput.main,  # sharded eddy core scaling
    "coalescing": bench_coalescing.main,    # adaptive micro-batch fusing
    "chaos": bench_chaos.main,              # fault injection + retry gates
    "serve": bench_serve.main,              # multi-tenant QueryService goodput
}


def dryrun_summary() -> None:
    """Roofline rows from the dry-run artifacts (EXPERIMENTS.md source)."""
    pat = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun", "*.json")
    files = sorted(glob.glob(pat))
    if not files:
        record("dryrun/none", 0.0, "run launch/dryrun.py first")
        return
    ok = err = skip = 0
    for f in files:
        r = json.load(open(f))
        s = r.get("status")
        ok += s == "ok"
        err += s == "error"
        skip += s == "skipped"
        if "roofline" in r:
            t = r["roofline"]["terms"]
            record(
                f"roofline/{r['arch']}/{r['shape']}",
                t["compute_s"] * 1e6,
                f"dominant={t['dominant']};fraction={t['roofline_fraction']:.3f};"
                f"mem_s={t['memory_s']:.3g};coll_s={t['collective_s']:.3g}",
            )
    record("dryrun/summary", 0.0, f"ok={ok};skipped={skip};errors={err}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(SUITES) + ["dryrun"])
    args = ap.parse_args()

    csv_header()
    failures = []
    suites = SUITES if args.only in (None, "dryrun") else {args.only: SUITES[args.only]}
    if args.only == "dryrun":
        suites = {}
    for name, fn in suites.items():
        try:
            fn()
        except Exception as e:
            failures.append(name)
            record(f"{name}/FAILED", 0.0, f"{type(e).__name__}:{e}")
            traceback.print_exc()
    if args.only in (None, "dryrun"):
        dryrun_summary()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
