"""Review triage: a multi-kernel text pipeline through AQP routing.

SELECT * FROM reviews
WHERE MoERouter(tokens) = expert_0          -- fused top-k gating kernel
  AND SSDScorer(tokens) > 0                 -- Mamba-2 SSD scan kernel
  AND rating <= 2;                          -- trivial, pushed to scan

Both UDF predicates come from the kernel-backed library (repro.udfs): the
router gates mean-pooled token embeddings through the moe_router Pallas
kernel; the scorer runs the SSD state-space scan over the token sequence.
The executor registers launch-timing hooks for the duration of the run, so
the routing statistics show per-kernel launch cost ("moe_router", "ssd")
next to the predicate-level stats the eddy policy ranks on — UDF cost is
profiled during execution, never estimated (§3.3).

  PYTHONPATH=src python examples/review_triage.py --reviews 300 --policy cost
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import udfs  # noqa: E402
from repro.core import Query, TrivialPredicate, optimize  # noqa: E402
from repro.core.policies import EDDY_POLICIES  # noqa: E402
from repro.data.text import make_reviews  # noqa: E402

SEQ = 64


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reviews", type=int, default=300)
    ap.add_argument("--policy", default="hydro", choices=sorted(EDDY_POLICIES))
    ap.add_argument("--expert", type=int, default=0)
    ap.add_argument("--max-rating", type=int, default=2)
    args = ap.parse_args()

    reviews = make_reviews(args.reviews)

    p_topic = udfs.topic_router_predicate(
        args.expert, n_experts=8, seq=SEQ, resource="tpu:0",
        name="MoERouter",
    )
    p_score = udfs.ssd_scorer_predicate(
        0.0, seq=SEQ, resource="tpu:1", name="SSDScorer",
    )

    def source(chunk=32):
        for i in range(0, len(reviews), chunk):
            part = reviews[i:i + chunk]
            toks = np.zeros((len(part), SEQ), np.int32)
            for j, r in enumerate(part):
                toks[j, : min(len(r.tokens), SEQ)] = r.tokens[:SEQ]
            yield {
                "tokens": toks,
                "rating": np.array([r.rating for r in part], np.int32),
                "_row_id": np.array([r.rid for r in part], np.int64),
            }

    q = Query(
        source=source(),
        predicates=[p_topic, p_score],
        trivial=[TrivialPredicate("rating", "<=", args.max_rating)],
    )
    plan = optimize(q, executor_kwargs=dict(
        policy=EDDY_POLICIES[args.policy](), max_workers=4,
    ))
    print("plan:", " -> ".join(plan.description))
    t0 = time.perf_counter()
    rows = plan.collect_rows()
    dt = time.perf_counter() - t0

    matched = rows["_row_id"].tolist()
    print(f"\ntriaged {len(matched)} low-rated expert-{args.expert} reviews "
          f"in {dt:.2f}s")

    # oracle re-evaluation: kernel predicates are pure functions of tokens
    kept = [r for r in reviews if r.rating <= args.max_rating]
    toks = np.zeros((len(kept), SEQ), np.int32)
    for j, r in enumerate(kept):
        toks[j, : min(len(r.tokens), SEQ)] = r.tokens[:SEQ]
    mask = np.ones(len(kept), bool)
    for p in (p_topic, p_score):
        mask &= p.mask_from_outputs(p.udf({"tokens": toks}))
    expect = {r.rid for r, m in zip(kept, mask) if m}
    assert set(matched) == expect, "AQP result must equal oracle filter"
    print("result equals oracle conjunctive evaluation ✓")

    snap = plan.executor.stats_snapshot()
    print("\npredicate routing statistics:")
    for name in ("MoERouter", "SSDScorer"):
        s = snap[name]
        print(f"  {name}: cost/row={s['cost_per_row']*1e3:.2f}ms "
              f"selectivity={s['selectivity']:.3f} score={s['score']*1e3:.2f}")
    print("per-kernel launch cost (launch hooks -> same StatsBoard):")
    for name in ("moe_router", "ssd"):
        if name in snap:
            s = snap[name]
            print(f"  {name}: cost/row={s['cost_per_row']*1e3:.3f}ms "
                  f"launches={int(s['batches'])}")


if __name__ == "__main__":
    main()
