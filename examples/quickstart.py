"""Quickstart: a Hydro AQP query in ~40 lines.

Two ML-ish predicates over a small table; the Eddy router discovers at run
time that `fast_pred` should run first, and the result set is identical to
naive evaluation (Hydro never trades accuracy).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import AQPExecutor, HydroPolicy, Predicate, UDF, make_batch  # noqa: E402

rng = np.random.default_rng(0)
x = rng.standard_normal((200, 16)).astype(np.float32)

# an expensive "model" (big matmul) and a cheap heuristic
W = rng.standard_normal((16, 512)).astype(np.float32)
expensive = UDF("embedder", fn=lambda d: np.tanh(d["x"] @ W).mean(1),
                columns=("x",), resource="tpu:0")
cheap = UDF("heuristic", fn=lambda d: d["x"].mean(1),
            columns=("x",), resource="cpu")

preds = [
    Predicate("embed_score", expensive, compare=lambda s: s > 0.0),
    Predicate("mean_filter", cheap, compare=lambda s: s > -0.5),
]

batches = [make_batch({"x": x[i:i + 10]}, np.arange(i, i + 10))
           for i in range(0, 200, 10)]

ex = AQPExecutor(preds, policy=HydroPolicy(), max_workers=4)
matched = sorted(int(i) for b in ex.run(iter(batches)) for i in b.row_ids)

naive = np.nonzero((np.tanh(x @ W).mean(1) > 0.0) & (x.mean(1) > -0.5))[0]
assert matched == naive.tolist(), "AQP must equal naive evaluation"

print(f"matched {len(matched)} rows (== naive evaluation)")
print("runtime statistics the router discovered:")
for name, s in ex.stats_snapshot().items():
    if name.startswith("_"):   # reserved sections (e.g. _arbiter counters)
        continue
    print(f"  {name}: cost/row={s['cost_per_row']*1e6:.1f}us "
          f"selectivity={s['selectivity']:.2f}")
