"""End-to-end training driver: train an LM for a few hundred steps with the
full production loop (prefetch pipeline, async checkpoints, watchdog,
resume). Defaults to a CPU-sized slice of smollm-135m so it finishes here;
pass --full-config to train the real 135M architecture (same code path —
on a TPU pod you would add --mesh and the FSDPxTP rules engage).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 300 --full-config --batch 8 --seq 512
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.launch.train import train_loop  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        # ~20M-param same-family slice: deep enough to show real learning
        cfg = dataclasses.replace(
            cfg.reduce_for_smoke(),
            num_layers=4, d_model=256, num_heads=8, num_kv_heads=2,
            head_dim=32, d_ff=1024, vocab_size=2048,
        )
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")
    out = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=50,
    )
    first = out["losses"][0]
    last = out["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(out['losses'])} steps "
          f"(stragglers flagged: {out['stragglers']})")
    assert last < first, "model must learn on the synthetic pattern"


if __name__ == "__main__":
    main()
