"""UC4: negative-food-review analytics with a REAL transformer LLM predicate.

SELECT * FROM foodreview
WHERE LLM('food or service?', review) = 'food' AND rating <= 1;

The LLM is a reduced decoder from the model zoo. --train-probe first
fine-tunes it for a few steps on labeled synthetic reviews (so the
predicate is actually accurate, not just expensive), then the query runs
through the full Hydro pipeline with the rating predicate pushed down and
data-aware Laminar balancing over the heavy-tailed review lengths.

  PYTHONPATH=src python examples/review_analytics.py --reviews 200 --train-probe 30
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import (  # noqa: E402
    DataAware, Predicate, Query, TrivialPredicate, UDF, optimize,
)
from repro.data.text import FOOD_WORDS, SERVICE_WORDS, make_reviews, topic_of_tokens  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.optim import AdamW, constant_schedule  # noqa: E402

MAX_LEN = 256


def pad(tokens_list):
    out = np.zeros((len(tokens_list), MAX_LEN), np.int32)
    for i, t in enumerate(tokens_list):
        out[i, : min(len(t), MAX_LEN)] = t[:MAX_LEN]
    return out


def train_probe(cfg, params, steps, seed=0):
    """Quick supervised fine-tune: next-token pools encode the topic."""
    opt = AdamW(schedule=constant_schedule(3e-3))
    state = opt.init(params)
    reviews = make_reviews(256, seed=seed + 100)
    toks = pad([r.tokens for r in reviews])
    # teacher forcing: predict the review's own tokens (topic words dominate)
    step = jax.jit(tf.make_train_step(cfg, opt))
    for i in range(steps):
        idx = np.random.default_rng(i).integers(0, len(reviews), 16)
        batch = {"tokens": jnp.asarray(toks[idx]),
                 "labels": jnp.asarray(np.roll(toks[idx], -1, axis=1))}
        params, state, m = step(params, state, batch)
        if (i + 1) % 10 == 0:
            print(f"  probe step {i+1}: loss={float(m['loss']):.3f}")
    return params


def build_llm_udf(params, cfg):
    food = jnp.asarray(FOOD_WORDS)
    service = jnp.asarray(SERVICE_WORDS)

    @jax.jit
    def score(tokens):
        logits = tf.forward(cfg, params, {"tokens": tokens})
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        mask = (tokens > 0)[..., None]
        pooled = jnp.where(mask, lp, 0.0).sum(1) / jnp.maximum(
            mask.sum(1), 1
        )
        return pooled[:, food].mean(-1) - pooled[:, service].mean(-1)

    return UDF(
        "LLM", fn=lambda d: np.asarray(score(jnp.asarray(d["tokens"]))),
        columns=("tokens",), resource="tpu:0",
        proxy_cost=lambda d: float((d["tokens"] > 0).sum()),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reviews", type=int, default=200)
    ap.add_argument("--train-probe", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduce_for_smoke()
    params = tf.init_params(cfg, jax.random.key(0))
    if args.train_probe:
        print(f"fine-tuning the LLM probe for {args.train_probe} steps...")
        params = train_probe(cfg, params, args.train_probe)

    reviews = make_reviews(args.reviews)
    llm = build_llm_udf(params, cfg)

    # probe accuracy on held-out reviews (vs actual token content)
    toks = pad([r.tokens for r in reviews])
    scores = llm({"tokens": toks})
    acc = np.mean([(s > 0) == (topic_of_tokens(r.tokens) == "food")
                   for s, r in zip(scores, reviews)])
    print(f"LLM probe accuracy vs content oracle: {acc:.2%}")

    def source(chunk=64):
        for i in range(0, len(reviews), chunk):
            part = reviews[i:i + chunk]
            yield {
                "tokens": pad([r.tokens for r in part]),
                "rating": np.array([r.rating for r in part], np.int32),
                "_row_id": np.array([r.rid for r in part], np.int64),
            }

    q = Query(
        source=source(),
        predicates=[Predicate("LLM_is_food", llm, compare=lambda s: s > 0)],
        trivial=[TrivialPredicate("rating", "<=", 1)],
    )
    plan = optimize(q, executor_kwargs=dict(
        laminar_policy_factory=DataAware, max_workers=4,
    ))
    print("plan:", " -> ".join(plan.description))
    t0 = time.perf_counter()
    rows = plan.collect_rows()
    dt = time.perf_counter() - t0

    matched = rows["_row_id"].tolist()
    print(f"\nmatched {len(matched)} negative food reviews in {dt:.2f}s")
    truth = {r.rid for r in reviews
             if r.rating <= 1 and topic_of_tokens(r.tokens) == "food"}
    inter = len(truth & set(matched))
    print(f"agreement with oracle topics: {inter}/{len(truth)} "
          f"(probe accuracy bounds this)")
    print("worker loads (data-aware balancing):",
          {k: round(v, 1) for k, v in plan.executor.stats.worker_load.items()})


if __name__ == "__main__":
    main()
