"""UC1: the lost-dog query (paper Listing 2) end to end.

SELECT id, bbox FROM video
CROSS APPLY UNNEST(ObjectDetector(frame)) AS Object(label, bbox, score)
WHERE Object.label='dog'
  AND DogBreedClassifier(Crop(frame, bbox)) = 'great dane'
  AND DogColorClassifier(Crop(frame, bbox)) = 'black';

Both predicates come from the kernel-backed library (repro.udfs): the
color classifier is the real HSV Pallas kernel — its per-launch timings
show up in the routing statistics under "hsv_color" because the executor
connects kernel launch hooks to its StatsBoard — and the breed classifier
is a planted-label stand-in with real XLA compute.
Compare routing policies with --policy {cost,score,selectivity,hydro}.

  PYTHONPATH=src python examples/lost_dog_query.py --frames 200 --policy cost
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import udfs  # noqa: E402
from repro.core import Query, optimize  # noqa: E402
from repro.core.policies import EDDY_POLICIES  # noqa: E402
from repro.data.video import (  # noqa: E402
    BREEDS, SyntheticVideo, crop_to_canonical,
)


def source(video, chunk=32):
    dogs = [o for o in video.objects if o.label == "dog"]
    for i in range(0, len(dogs), chunk):
        part = dogs[i:i + chunk]
        crops = np.stack(
            [crop_to_canonical(video.crop(o.frame_id, o.bbox)) for o in part]
        ).astype(np.float32)
        yield {
            "crop": crops,
            "frame_id": np.array([o.frame_id for o in part]),
            "bbox": np.array([o.bbox for o in part]),
            "breed_gt": np.array([BREEDS.index(o.breed) for o in part]),
            "_row_id": np.arange(i, i + len(part)),
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=200)
    ap.add_argument("--policy", default="hydro", choices=sorted(EDDY_POLICIES))
    ap.add_argument("--breed", default="great dane")
    ap.add_argument("--color", default="black")
    args = ap.parse_args()

    video = SyntheticVideo(num_frames=args.frames, seed=7)

    p_breed = udfs.planted_classifier(
        "DogBreedClassifier", BREEDS.index(args.breed),
        label_column="breed_gt", pixel_column="crop", resource="tpu:0",
    )
    p_color = udfs.color_predicate(
        args.color, size=64, impl="pallas", resource="cpu",
        name="DogColorClassifier",
    )

    q = Query(source=source(video), predicates=[p_breed, p_color],
              project=("frame_id", "bbox"))
    plan = optimize(q, executor_kwargs=dict(
        policy=EDDY_POLICIES[args.policy](), max_workers=4,
    ))
    print("plan:", " -> ".join(plan.description))
    t0 = time.perf_counter()
    rows = plan.collect_rows()
    dt = time.perf_counter() - t0

    n = len(rows["_row_id"])
    print(f"\nfound {n} {args.color} {args.breed} sightings in {dt:.2f}s:")
    for fid, bbox in list(zip(rows["frame_id"], rows["bbox"]))[:10]:
        print(f"  frame {int(fid):4d}  bbox {tuple(int(b) for b in bbox)}")
    if n > 10:
        print(f"  ... and {n - 10} more")
    print("\nrouting statistics (collected at run time, no priors):")
    pred_names = {p.name for p in q.predicates}
    snap = plan.executor.stats_snapshot()
    for name, s in snap.items():
        if name not in pred_names:
            continue
        print(f"  {name}: cost/row={s['cost_per_row']*1e3:.2f}ms "
              f"selectivity={s['selectivity']:.3f} score={s['score']*1e3:.2f}")
    kernel_rows = {n: s for n, s in snap.items()
                   if n not in pred_names and not n.startswith("_")}
    if kernel_rows:
        print("per-kernel launch cost (launch hooks -> same StatsBoard):")
        for name, s in kernel_rows.items():
            print(f"  {name}: cost/row={s['cost_per_row']*1e3:.3f}ms "
                  f"launches={int(s['batches'])}")


if __name__ == "__main__":
    main()
