"""UC2 + UC3: warehouse-safety analytics with result reuse and Laminar.

Runs the paper's exploratory sequence (Listing 3):
  Q1: ObjectDetector over frames [A, B)        -> populates cache
  Q2: HardHatDetector over frames [C, D)       -> populates cache
  Q3: person AND no-hardhat over ALL frames    -> recurrent query

Q3 executes twice — cost-driven vs reuse-aware — and reports how much of
the work the reuse-aware router avoided. GACU worker counts show Laminar
scaling on the expensive predicate.

Both detectors are ``repro.udfs.planted_detector``s: real HSV-kernel
compute with planted labels, so the executor's launch hook records genuine
per-launch kernel cost under "hsv_color" in the routing statistics.

  PYTHONPATH=src python examples/warehouse_safety.py --frames 400
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro import udfs  # noqa: E402
from repro.core import (  # noqa: E402
    AQPExecutor, CostDriven, ReuseAware, ReuseCache, make_batch,
)


def frame_batches(n_frames, work_dim=96, per=10, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(0, n_frames, per):
        rid = np.arange(i, min(i + per, n_frames))
        yield make_batch(
            {"frame": rng.integers(0, 255, (len(rid), work_dim, work_dim, 3)
                                   ).astype(np.float32),
             "rid": rid},
            rid,
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=400)
    args = ap.parse_args()
    n = args.frames
    rng = np.random.default_rng(1)
    person = rng.random(n) < 0.5
    nohat = rng.random(n) < 0.3

    p_obj = udfs.planted_detector("person", person, work_dim=96)
    p_hat = udfs.planted_detector("no_hardhat", nohat, work_dim=96)
    obj_udf, hat_udf = p_obj.udf, p_hat.udf

    def primed_cache():
        """Q1/Q2: exploratory queries populate a fresh cache."""
        cache = ReuseCache()
        seg = n // 4
        for name, udf, lo, hi in (
            ("Q1 ObjectDetector", obj_udf, 0, 2 * seg),
            ("Q2 HardHatDetector", hat_udf, 2 * seg, n),
        ):
            rid = np.arange(lo, hi)
            frames = np.zeros((len(rid), 96, 96, 3), np.float32)
            t0 = time.perf_counter()
            out = udf({"frame": frames, "rid": rid})
            cache.put(udf.name, rid, out)
            print(f"{name}: cached frames [{lo},{hi}) in "
                  f"{time.perf_counter()-t0:.2f}s")
        return cache

    # ---- Q3 recurrent query: cost-driven vs reuse-aware (fresh identical
    # caches, so the comparison is about ROUTING, not cache state) ----
    results = {}
    for label, policy in (("cost-driven", CostDriven()),
                          ("reuse-aware", ReuseAware())):
        ex = AQPExecutor([p_obj, p_hat], policy=policy, cache=primed_cache(),
                         max_workers=8, cost_alpha=0.05)
        t0 = time.perf_counter()
        got = {int(i) for b in ex.run(iter(frame_batches(n))) for i in b.row_ids}
        dt = time.perf_counter() - t0
        snap = ex.stats_snapshot()
        results[label] = got
        print(f"\nQ3 [{label}] -> {len(got)} unsafe frames in {dt:.2f}s")
        for pname in ("person", "no_hardhat"):
            s = snap[pname]
            print(f"  {pname}: cache_hit_rate={s['cache_hit_rate']:.2f} "
                  f"est_cost/row={s['cost_per_row']*1e3:.2f}ms")
        if "hsv_color" in snap:  # launch hook: real per-kernel launch cost
            s = snap["hsv_color"]
            print(f"  hsv_color kernel: cost/row={s['cost_per_row']*1e3:.3f}ms"
                  f" launches={int(s['batches'])}")
        print(f"  GACU active workers: {ex.active_worker_counts()}")
        print(f"  arbiter (leases/releases/handoffs): {snap['_arbiter']}")

    assert results["cost-driven"] == results["reuse-aware"]
    expect = set(np.nonzero(person & nohat)[0].tolist())
    assert results["reuse-aware"] == expect, "must match ground truth"
    print("\nresults identical across policies and equal to ground truth ✓")


if __name__ == "__main__":
    main()
