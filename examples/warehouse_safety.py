"""UC2 + UC3: warehouse-safety analytics with result reuse and Laminar.

Runs the paper's exploratory sequence (Listing 3):
  Q1: ObjectDetector over frames [A, B)        -> populates cache
  Q2: HardHatDetector over frames [C, D)       -> populates cache
  Q3: person AND no-hardhat over ALL frames    -> recurrent query

Q3 executes twice — cost-driven vs reuse-aware — and reports how much of
the work the reuse-aware router avoided. GACU worker counts show Laminar
scaling on the expensive predicate.

  PYTHONPATH=src python examples/warehouse_safety.py --frames 400
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    AQPExecutor, CostDriven, Predicate, ReuseAware, ReuseCache, UDF, make_batch,
)
from repro.kernels import ops  # noqa: E402


def make_detector(name, planted_mask, work_dim=96):
    """Real compute (HSV kernel over a frame-sized buffer) + planted labels."""
    def fn(d):
        _ = ops.hsv_color_classify(
            d["frame"].reshape(-1, work_dim, work_dim, 3), impl="xla"
        )
        return planted_mask[d["rid"]]

    return UDF(name, fn, columns=("frame", "rid"), resource="tpu:0", bucket=False)


def frame_batches(n_frames, work_dim=96, per=10, seed=0):
    rng = np.random.default_rng(seed)
    for i in range(0, n_frames, per):
        rid = np.arange(i, min(i + per, n_frames))
        yield make_batch(
            {"frame": rng.integers(0, 255, (len(rid), work_dim, work_dim, 3)
                                   ).astype(np.float32),
             "rid": rid},
            rid,
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=400)
    args = ap.parse_args()
    n = args.frames
    rng = np.random.default_rng(1)
    person = rng.random(n) < 0.5
    nohat = rng.random(n) < 0.3

    obj_udf = make_detector("ObjectDetector", person)
    hat_udf = make_detector("HardHatDetector", nohat)
    p_obj = Predicate("person", obj_udf, compare=lambda o: o.astype(bool))
    p_hat = Predicate("no_hardhat", hat_udf, compare=lambda o: o.astype(bool))

    def primed_cache():
        """Q1/Q2: exploratory queries populate a fresh cache."""
        cache = ReuseCache()
        seg = n // 4
        for name, udf, lo, hi in (
            ("Q1 ObjectDetector", obj_udf, 0, 2 * seg),
            ("Q2 HardHatDetector", hat_udf, 2 * seg, n),
        ):
            rid = np.arange(lo, hi)
            frames = np.zeros((len(rid), 96, 96, 3), np.float32)
            t0 = time.perf_counter()
            out = udf({"frame": frames, "rid": rid})
            cache.put(udf.name, rid, out)
            print(f"{name}: cached frames [{lo},{hi}) in "
                  f"{time.perf_counter()-t0:.2f}s")
        return cache

    # ---- Q3 recurrent query: cost-driven vs reuse-aware (fresh identical
    # caches, so the comparison is about ROUTING, not cache state) ----
    results = {}
    for label, policy in (("cost-driven", CostDriven()),
                          ("reuse-aware", ReuseAware())):
        ex = AQPExecutor([p_obj, p_hat], policy=policy, cache=primed_cache(),
                         max_workers=8, cost_alpha=0.05)
        t0 = time.perf_counter()
        got = {int(i) for b in ex.run(iter(frame_batches(n))) for i in b.row_ids}
        dt = time.perf_counter() - t0
        snap = ex.stats_snapshot()
        results[label] = got
        print(f"\nQ3 [{label}] -> {len(got)} unsafe frames in {dt:.2f}s")
        for pname, s in snap.items():
            print(f"  {pname}: cache_hit_rate={s['cache_hit_rate']:.2f} "
                  f"est_cost/row={s['cost_per_row']*1e3:.2f}ms")
        print(f"  GACU active workers: {ex.active_worker_counts()}")

    assert results["cost-driven"] == results["reuse-aware"]
    expect = set(np.nonzero(person & nohat)[0].tolist())
    assert results["reuse-aware"] == expect, "must match ground truth"
    print("\nresults identical across policies and equal to ground truth ✓")


if __name__ == "__main__":
    main()
