"""Synthetic review corpus for UC4 (LLM predicate over food reviews).

Reviews have heavy-tailed length distribution (the workload-imbalance driver
in the paper's Fig 13/14) and planted topic ("food" | "service") + rating
ground truth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

FOOD_WORDS = list(range(10, 60))
SERVICE_WORDS = list(range(60, 110))


@dataclass
class Review:
    rid: int
    tokens: np.ndarray   # int32
    rating: int          # 1..5
    topic: str           # "food" | "service"


def make_reviews(n: int = 600, *, seed: int = 0, vocab: int = 256) -> List[Review]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        topic = "food" if rng.random() < 0.5 else "service"
        # heavy-tailed lengths: many short, few very long
        length = int(np.clip(rng.lognormal(3.0, 0.9), 8, 512))
        pool = FOOD_WORDS if topic == "food" else SERVICE_WORDS
        toks = rng.choice(pool, size=length).astype(np.int32)
        # sprinkle generic words
        generic = rng.integers(110, vocab, size=length).astype(np.int32)
        mask = rng.random(length) < 0.3
        toks = np.where(mask, generic, toks)
        rating = int(rng.integers(1, 6))
        out.append(Review(i, toks, rating, topic))
    return out


def topic_of_tokens(tokens: np.ndarray) -> str:
    """Ground-truth oracle used to verify the LLM predicate."""
    food = int(np.isin(tokens, FOOD_WORDS).sum())
    service = int(np.isin(tokens, SERVICE_WORDS).sum())
    return "food" if food >= service else "service"
