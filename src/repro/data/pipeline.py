"""Data pipeline: deterministic synthetic sources + threaded prefetch.

The pipeline shape matches a production layout: Source (resumable iterator,
seeded) -> Batcher -> Prefetcher (background thread, bounded queue — the
host-side analogue of Hydro's EddyPull) -> device placement with the mesh's
batch sharding.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.distributed.sharding import Rules, named_sharding


class TokenSource:
    """Deterministic synthetic LM tokens with a learnable structure.

    Tokens follow a noisy periodic pattern so a real model can reduce loss
    on it (used by examples/train_lm.py to show learning).
    """

    def __init__(self, vocab_size: int, seq_len: int, *, seed: int = 0, period: int = 17):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed
        self.period = period
        self._step = 0

    def state(self) -> Dict:
        return {"step": self._step}

    def restore(self, state: Dict) -> None:
        self._step = int(state["step"])

    def next(self, batch: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        base = rng.integers(0, self.period, size=(batch, 1))
        pos = np.arange(self.seq_len + 1)[None, :]
        toks = ((base + pos) * 31 % self.period) % self.vocab_size
        noise = rng.integers(0, self.vocab_size, size=toks.shape)
        mask = rng.random(toks.shape) < 0.05
        toks = np.where(mask, noise, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch with a bounded queue (backpressure)."""

    def __init__(self, fn: Callable[[], Dict], *, depth: int = 2):
        self.fn = fn
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            try:
                item = self.fn()
            except Exception as e:  # surface producer errors to the consumer
                self.q.put(e)
                return
            while not self._stop.is_set():
                try:
                    self.q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self):
        item = self.q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def stop(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


def shard_batch(batch: Dict[str, np.ndarray], mesh=None, rules: Optional[Rules] = None,
                logical: Optional[Dict[str, str]] = None):
    """Place a host batch onto the mesh with batch sharding."""
    if mesh is None or rules is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    logical = logical or {}
    out = {}
    for k, v in batch.items():
        dims = logical.get(k, "batch" + " ." * (v.ndim - 1))
        out[k] = jax.device_put(v, named_sharding(v.shape, dims, rules, mesh))
    return out


def data_iterator(source: TokenSource, batch_size: int, *, prefetch: int = 2) -> Iterator[Dict]:
    pf = Prefetcher(lambda: source.next(batch_size), depth=prefetch)
    try:
        while True:
            yield pf.next()
    finally:
        pf.stop()
