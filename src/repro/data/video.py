"""Synthetic video + ObjectDetector stub for the Hydro use cases.

``SyntheticVideo`` plants colored "dog" rectangles with known breed/color
ground truth into random-noise frames, so UC1/UC2 queries have exact
expected answers (AQP must return the same rows as naive evaluation — the
paper's no-accuracy-tradeoff claim is testable).

``ObjectDetectorStub`` plays the role of YOLO: it returns the planted boxes
with configurable cost (a real matmul of calibrated size, so predicate cost
is real compute, not sleep()).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.kernels import ref as kref

BREEDS = ("great dane", "labrador retriever", "poodle", "beagle")
COLORS = ("black", "gray", "yellow", "white")

_COLOR_RGB = {
    "black": (10, 10, 10),
    "gray": (120, 120, 120),
    "yellow": (230, 210, 40),
    "white": (240, 240, 240),
}


@dataclass
class PlantedObject:
    frame_id: int
    label: str            # "dog" | "person" | ...
    breed: str
    color: str
    bbox: Tuple[int, int, int, int]  # x0, y0, x1, y1
    score: float


@dataclass
class SyntheticVideo:
    num_frames: int = 600
    height: int = 96
    width: int = 128
    seed: int = 0
    dog_rate: float = 0.7          # fraction of frames containing a dog
    breed_probs: Tuple[float, ...] = (0.25, 0.06, 0.39, 0.30)
    color_probs: Tuple[float, ...] = (0.35, 0.06, 0.29, 0.30)
    objects: List[PlantedObject] = field(default_factory=list)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        for f in range(self.num_frames):
            if rng.random() < self.dog_rate:
                breed = rng.choice(BREEDS, p=self.breed_probs)
                color = rng.choice(COLORS, p=self.color_probs)
                w = int(rng.integers(24, 56))
                h = int(rng.integers(24, 56))
                x0 = int(rng.integers(0, self.width - w))
                y0 = int(rng.integers(0, self.height - h))
                self.objects.append(
                    PlantedObject(f, "dog", str(breed), str(color),
                                  (x0, y0, x0 + w, y0 + h), 0.9)
                )
            if rng.random() < 0.3:
                self.objects.append(
                    PlantedObject(f, "person", "", "", (0, 0, 16, 16), 0.8)
                )

    def frame(self, frame_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, frame_id))
        img = rng.integers(60, 200, size=(self.height, self.width, 3)).astype(np.uint8)
        for obj in self.objects:
            if obj.frame_id == frame_id and obj.label == "dog":
                x0, y0, x1, y1 = obj.bbox
                rgb = _COLOR_RGB[obj.color]
                img[y0:y1, x0:x1] = np.asarray(rgb, np.uint8)[None, None]
        return img

    def crop(self, frame_id: int, bbox) -> np.ndarray:
        x0, y0, x1, y1 = bbox
        return self.frame(frame_id)[y0:y1, x0:x1]

    def detections(self, frame_id: int) -> List[PlantedObject]:
        return [o for o in self.objects if o.frame_id == frame_id]

    def ground_truth(self, breed: str, color: str) -> List[PlantedObject]:
        return [
            o for o in self.objects
            if o.label == "dog" and o.breed == breed and o.color == color
        ]


def crop_to_canonical(crop: np.ndarray, size: int = 64) -> np.ndarray:
    """Nearest-neighbour resize to a canonical square (TPU shape bucketing)."""
    h, w = crop.shape[:2]
    ys = (np.arange(size) * h // size).clip(0, h - 1)
    xs = (np.arange(size) * w // size).clip(0, w - 1)
    return crop[ys][:, xs]


def classify_color_batch(crops: np.ndarray) -> List[str]:
    """Ground-truth-free color labels via the HSV kernel oracle."""
    import jax.numpy as jnp

    hist, label = kref.hsv_color_classify(jnp.asarray(crops, jnp.float32))
    return [kref.COLOR_NAMES[int(i)] for i in np.asarray(label)]
