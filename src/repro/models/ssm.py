"""SSM family (mamba2-370m): attention-free SSD (state-space duality).

Block: in-proj -> depthwise causal conv over [x;B;C] -> SSD (chunked kernel,
kernels/ssd.py) -> gated RMSNorm -> out-proj. Serving state is O(1) in
context length: conv tail + (H, P, N) SSM state — this is why mamba2 runs
the long_500k cell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, named_sharding
from repro.kernels import ops
from repro.models import transformer as tf
from repro.models.layers import (
    NULL_CTX, ShardCtx, dtype_of, embed_tokens, lm_logits, rms_norm,
    softmax_xent, trunc_normal,
)

SDS = jax.ShapeDtypeStruct


def _dims(cfg):
    di = cfg.d_inner                  # 2 * d_model
    h = cfg.ssm_heads                 # di / head_dim
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    g = cfg.ssm_groups
    conv_ch = di + 2 * g * n
    return di, h, p, n, g, conv_ch


# --------------------------------------------------------------------------- #
# parameters                                                                   #
# --------------------------------------------------------------------------- #
def layer_param_shapes(cfg) -> Dict[str, SDS]:
    d, L = cfg.d_model, cfg.num_layers
    di, h, p, n, g, conv_ch = _dims(cfg)
    cw = cfg.ssm_conv_width
    dt = dtype_of(cfg)
    return {
        "norm": SDS((L, d), dt),
        "w_z": SDS((L, d, di), dt),
        "w_x": SDS((L, d, di), dt),
        "w_B": SDS((L, d, g * n), dt),
        "w_C": SDS((L, d, g * n), dt),
        "w_dt": SDS((L, d, h), dt),
        "dt_bias": SDS((L, h), dt),
        "A_log": SDS((L, h), dt),
        "D_skip": SDS((L, h), dt),
        "conv_w": SDS((L, conv_ch, cw), dt),
        "conv_b": SDS((L, conv_ch), dt),
        "gated_norm": SDS((L, di), dt),
        "w_out": SDS((L, di, d), dt),
    }


LAYER_LOGICAL = {
    "norm": "layers .",
    "w_z": "layers d_model_w ssm_inner",
    "w_x": "layers d_model_w ssm_inner",
    "w_B": "layers d_model_w .",
    "w_C": "layers d_model_w .",
    "w_dt": "layers d_model_w ssm_heads",
    "dt_bias": "layers ssm_heads",
    "A_log": "layers ssm_heads",
    "D_skip": "layers ssm_heads",
    "conv_w": "layers . conv",
    "conv_b": "layers .",
    "gated_norm": "layers ssm_inner",
    "w_out": "layers ssm_inner d_model_w",
}


def param_shapes(cfg) -> Dict:
    d, vp = cfg.d_model, cfg.vocab_padded
    dt = dtype_of(cfg)
    return {
        "embed": SDS((vp, d), dt),
        "out_head": SDS((d, vp), dt),
        "final_norm": SDS((d,), dt),
        "layers": layer_param_shapes(cfg),
    }


def param_logical(cfg) -> Dict:
    return {
        "embed": "vocab d_model_w",
        "out_head": "d_model_w vocab",
        "final_norm": ".",
        "layers": LAYER_LOGICAL,
    }


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))

    def mk(k, sds):
        if sds.shape and len(sds.shape) >= 2:
            return trunc_normal(k, sds.shape, 0.02, sds.dtype)
        return jnp.full(sds.shape, 0.1, sds.dtype)  # A_log/dt_bias benign init

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, flat)])


def param_count(cfg) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg) -> int:
    return param_count(cfg)


# --------------------------------------------------------------------------- #
# block                                                                        #
# --------------------------------------------------------------------------- #
def _proj_in(cfg, lp, x_in, ctx):
    di, h, p, n, g, conv_ch = _dims(cfg)
    dt = x_in.dtype
    z = jnp.einsum("bsd,dk->bsk", x_in, lp["w_z"].astype(dt))
    xi = jnp.einsum("bsd,dk->bsk", x_in, lp["w_x"].astype(dt))
    Bm = jnp.einsum("bsd,dk->bsk", x_in, lp["w_B"].astype(dt))
    Cm = jnp.einsum("bsd,dk->bsk", x_in, lp["w_C"].astype(dt))
    dtv = jnp.einsum("bsd,dk->bsk", x_in, lp["w_dt"].astype(dt))
    z = ctx.constrain(z, "batch seq ssm_inner")
    xi = ctx.constrain(xi, "batch seq ssm_inner")
    return z, xi, Bm, Cm, dtv


def _conv_xbc(cfg, lp, xi, Bm, Cm, state=None):
    """Depthwise causal conv over concat([x, B, C]); returns pieces + tail."""
    from repro.models.hybrid import causal_conv1d

    di, h, p, n, g, conv_ch = _dims(cfg)
    xbc = jnp.concatenate([xi, Bm, Cm], axis=-1)   # (B, S, conv_ch)
    out = causal_conv1d(xbc, lp["conv_w"], lp["conv_b"], state)
    out = jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype)
    cw = cfg.ssm_conv_width
    tail_src = xbc if state is None else jnp.concatenate(
        [state.astype(xbc.dtype), xbc], axis=1
    )
    pad = cw - 1 - tail_src.shape[1]
    if pad > 0:
        tail_src = jnp.pad(tail_src, ((0, 0), (pad, 0), (0, 0)))
    tail = tail_src[:, -(cw - 1):]
    return out[..., :di], out[..., di : di + g * n], out[..., di + g * n :], tail


def ssm_block(cfg, lp, hin, ctx: ShardCtx, state=None):
    """state: None (train) or {"conv": (B,cw-1,conv_ch), "ssm": (B,H,P,N)}."""
    di, h, p, n, g, conv_ch = _dims(cfg)
    b, s, _ = hin.shape
    x_in = rms_norm(hin, lp["norm"], cfg.norm_eps)
    z, xi, Bm, Cm, dtv = _proj_in(cfg, lp, x_in, ctx)
    conv_state = None if state is None else state["conv"]
    xi, Bm, Cm, tail = _conv_xbc(cfg, lp, xi, Bm, Cm, conv_state)

    dt = jax.nn.softplus(
        dtv.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32)
    )                                                 # (B, S, H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))     # (H,) negative
    xh = xi.reshape(b, s, h, p)
    Bh = Bm.reshape(b, s, g, n)
    Ch = Cm.reshape(b, s, g, n)

    h0 = None if state is None else state["ssm"]
    y, h_last = ops.ssd(xh, dt, A, Bh, Ch, h0, chunk=min(64, s), impl=cfg.attention_impl)
    y = y + xh * lp["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, lp["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, lp["w_out"].astype(y.dtype))
    out = ctx.constrain(out, "batch seq d_model")
    hout = hin + out
    if state is None:
        return hout, None
    return hout, {"conv": tail, "ssm": h_last}


def _ssm_decode_block(cfg, lp, hin, ctx, state):
    """Single-token step using the O(1) recurrent form."""
    di, h, p, n, g, conv_ch = _dims(cfg)
    b = hin.shape[0]
    x_in = rms_norm(hin, lp["norm"], cfg.norm_eps)
    z, xi, Bm, Cm, dtv = _proj_in(cfg, lp, x_in, ctx)
    xi1, Bm1, Cm1, tail = _conv_xbc(cfg, lp, xi, Bm, Cm, state["conv"])

    dt = jax.nn.softplus(
        dtv[:, 0].astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32)
    )                                                 # (B, H)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    xh = xi1[:, 0].reshape(b, h, p)
    Bh = Bm1[:, 0].reshape(b, g, n)
    Ch = Cm1[:, 0].reshape(b, g, n)
    y, h_new = ops.ssd_decode_step(xh, dt, A, Bh, Ch, state["ssm"])
    y = y + xh * lp["D_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, lp["gated_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, lp["w_out"].astype(y.dtype))
    return hin + out, {"conv": tail, "ssm": h_new}


# --------------------------------------------------------------------------- #
# forward / loss / serving                                                     #
# --------------------------------------------------------------------------- #
def forward(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)

    def body(carry, lp):
        hh, _ = ssm_block(cfg, lp, carry, ctx)
        return hh, None

    h, _ = jax.lax.scan(tf._remat(cfg, body), h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_logits(h, params["out_head"], cfg.vocab_size, ctx)


def loss_fn(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    logits = forward(cfg, params, batch, ctx)
    loss = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss}


def make_train_step(cfg, optimizer, ctx: ShardCtx = NULL_CTX):
    return tf.make_train_step(cfg, optimizer, ctx, loss=loss_fn)


def cache_shapes(cfg, batch: int, seq_len: int):
    di, h, p, n, g, conv_ch = _dims(cfg)
    L, cw = cfg.num_layers, cfg.ssm_conv_width
    dt = dtype_of(cfg)
    shapes = {
        "conv": SDS((L, batch, cw - 1, conv_ch), dt),
        "ssm": SDS((L, batch, h, p, n), jnp.float32),
        "lengths": SDS((batch,), jnp.int32),
    }
    logical = {
        "conv": "layers batch . .",
        "ssm": "layers batch ssm_heads . .",
        "lengths": "batch",
    }
    return shapes, logical


def prefill(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    di, hh_, p, n, g, conv_ch = _dims(cfg)
    zero = {
        "conv": jnp.zeros((b, cfg.ssm_conv_width - 1, conv_ch), h.dtype),
        "ssm": jnp.zeros((b, hh_, p, n), jnp.float32),
    }

    def body(carry, lp):
        hh, st = ssm_block(cfg, lp, carry, ctx, zero)
        return hh, st

    h, cache = jax.lax.scan(tf._remat(cfg, body), h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h[:, -1:], params["out_head"], cfg.vocab_size, ctx)[:, 0]
    cache = dict(cache, lengths=jnp.full((b,), s, jnp.int32))
    return cache, logits


def decode_step(cfg, params, cache, batch, ctx: ShardCtx = NULL_CTX):
    token = batch["token"]
    h = embed_tokens(token[:, None], params["embed"], ctx)

    def body(carry, xs):
        lp, conv, ssm_st = xs
        hh, nst = _ssm_decode_block(cfg, lp, carry, ctx, {"conv": conv, "ssm": ssm_st})
        return hh, nst

    h, ncache = jax.lax.scan(body, h, (params["layers"], cache["conv"], cache["ssm"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h, params["out_head"], cfg.vocab_size, ctx)[:, 0]
    new_cache = {
        "conv": ncache["conv"], "ssm": ncache["ssm"], "lengths": cache["lengths"] + 1
    }
    return new_cache, logits


def input_specs(cfg, shape, mesh=None, rules: Rules | None = None) -> Dict[str, SDS]:
    return tf.input_specs(cfg, shape, mesh, rules)


roofline_units = tf.roofline_units
