from repro.models.registry import family_module, model_api  # noqa: F401
