"""Hybrid family (recurrentgemma-9b): Griffin-style RG-LRU + local attention.

Block pattern = (rglru, rglru, local-attn) repeated; remainder layers are
rglru. The stack is TWO scans — one over (rg, rg, attn) super-blocks, one
over the remainder rg blocks — so the HLO stays O(1) in depth and the
roofline delta-lowering gets exact per-super-block costs.

RG-LRU gates use Griffin's block-diagonal linears (nb=16 blocks); the
recurrence itself is the Pallas kernel (kernels/rglru.py) on TPU and the
associative-scan oracle on the XLA path. Serving state is O(1): conv tail
(width-1 inputs) + LRU hidden state + a local-attention ring buffer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, named_sharding
from repro.kernels import ops
from repro.models import attention as attn
from repro.models import transformer as tf
from repro.models.layers import (
    NULL_CTX, ShardCtx, dtype_of, embed_tokens, lm_logits, rms_norm,
    softmax_xent, swiglu_mlp, trunc_normal,
)

SDS = jax.ShapeDtypeStruct

NB = 16          # block-diagonal gate blocks (Griffin)
CONV_W = 4       # temporal conv width
RG_C = 8.0       # RG-LRU `c` constant


def _counts(cfg):
    return cfg.num_layers // 3, cfg.num_layers % 3  # (groups, rest rg layers)


# --------------------------------------------------------------------------- #
# parameters                                                                   #
# --------------------------------------------------------------------------- #
def _mlp_shapes(cfg, L, dt):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mlp_norm": SDS((L, d), dt),
        "w_gate": SDS((L, d, f), dt),
        "w_up": SDS((L, d, f), dt),
        "w_down": SDS((L, f, d), dt),
    }


_MLP_LOGICAL = {
    "mlp_norm": "layers .",
    "w_gate": "layers d_model_w d_ff",
    "w_up": "layers d_model_w d_ff",
    "w_down": "layers d_ff d_model_w",
}


def rg_param_shapes(cfg, L):
    d = cfg.d_model
    w = cfg.d_model  # lru width == d_model for recurrentgemma
    dt = dtype_of(cfg)
    shapes = {
        "norm": SDS((L, d), dt),
        "w_x": SDS((L, d, w), dt),
        "w_g": SDS((L, d, w), dt),
        "conv_w": SDS((L, w, CONV_W), dt),
        "conv_b": SDS((L, w), dt),
        "w_r": SDS((L, NB, w // NB, w // NB), dt),
        "b_r": SDS((L, w), dt),
        "w_i": SDS((L, NB, w // NB, w // NB), dt),
        "b_i": SDS((L, w), dt),
        "a_param": SDS((L, w), dt),
        "w_out": SDS((L, w, d), dt),
    }
    shapes.update(_mlp_shapes(cfg, L, dt))
    return shapes


RG_LOGICAL = {
    "norm": "layers .",
    "w_x": "layers d_model_w lru",
    "w_g": "layers d_model_w lru",
    "conv_w": "layers lru conv",
    "conv_b": "layers lru",
    "w_r": "layers lru_blocks . .",
    "b_r": "layers lru",
    "w_i": "layers lru_blocks . .",
    "b_i": "layers lru",
    "a_param": "layers lru",
    "w_out": "layers lru d_model_w",
    **_MLP_LOGICAL,
}


def attn_param_shapes(cfg, L):
    shapes = tf.layer_param_shapes(dataclasses.replace(cfg, num_layers=L))
    for k in ("mlp_norm", "w_gate", "w_up", "w_down"):
        pass  # attn layer keeps its own MLP (every Griffin block has one)
    return shapes


def param_shapes(cfg) -> Dict:
    g, r = _counts(cfg)
    d, vp = cfg.d_model, cfg.vocab_padded
    dt = dtype_of(cfg)
    return {
        "embed": SDS((vp, d), dt),
        "out_head": SDS((d, vp), dt),
        "final_norm": SDS((d,), dt),
        "groups": {
            "rg1": rg_param_shapes(cfg, g),
            "rg2": rg_param_shapes(cfg, g),
            "attn": attn_param_shapes(cfg, g),
        },
        "rest": rg_param_shapes(cfg, r),
    }


def param_logical(cfg) -> Dict:
    return {
        "embed": "vocab d_model_w",
        "out_head": "d_model_w vocab",
        "final_norm": ".",
        "groups": {
            "rg1": RG_LOGICAL,
            "rg2": RG_LOGICAL,
            "attn": tf.layer_param_logical(cfg),
        },
        "rest": RG_LOGICAL,
    }


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))

    def mk(k, sds):
        if sds.shape and len(sds.shape) >= 2:
            return trunc_normal(k, sds.shape, 0.02, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, flat)])


def param_count(cfg) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg) -> int:
    return param_count(cfg)


# --------------------------------------------------------------------------- #
# RG-LRU block                                                                 #
# --------------------------------------------------------------------------- #
def _blockdiag(x, w, b):
    """x (B,S,W) @ block-diagonal (NB, W/NB, W/NB) + b."""
    bsz, s, wdim = x.shape
    xb = x.reshape(bsz, s, NB, wdim // NB)
    y = jnp.einsum("bsnw,nwv->bsnv", xb, w.astype(x.dtype))
    return y.reshape(bsz, s, wdim) + b.astype(x.dtype)


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x (B,S,W), w (W,cw). state: (B,cw-1,W) tail."""
    cw = w.shape[-1]
    if state is None:
        pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    out = sum(pad[:, j : j + s] * w[:, j].astype(x.dtype) for j in range(cw))
    return out + b.astype(x.dtype)


def rg_block(cfg, lp, h, ctx: ShardCtx, state=None):
    """Griffin recurrent block (+MLP). state: None (train) or (conv, h_lru)."""
    x_in = rms_norm(h, lp["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x_in, lp["w_g"].astype(x_in.dtype)).astype(jnp.float32)
    ).astype(x_in.dtype)
    gate = ctx.constrain(gate, "batch seq lru")
    xr_raw = jnp.einsum("bsd,dw->bsw", x_in, lp["w_x"].astype(x_in.dtype))
    xr_raw = ctx.constrain(xr_raw, "batch seq lru")

    conv_state = None if state is None else state["conv"]
    xr = causal_conv1d(xr_raw, lp["conv_w"], lp["conv_b"], conv_state)
    r = _blockdiag(xr, lp["w_r"], lp["b_r"])
    i = _blockdiag(xr, lp["w_i"], lp["b_i"])
    h0 = None if state is None else state["h"]
    y, h_last = ops.rglru(
        xr, r, i, lp["a_param"], h0, c=RG_C, impl=cfg.attention_impl
    )
    out = jnp.einsum("bsw,wd->bsd", y * gate, lp["w_out"].astype(y.dtype))
    out = ctx.constrain(out, "batch seq d_model")
    h = h + out
    m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    h = h + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)

    if state is None:
        return h, None
    cw = CONV_W
    tail_src = jnp.concatenate([state["conv"].astype(xr_raw.dtype), xr_raw], axis=1)
    new_state = {"conv": tail_src[:, -(cw - 1):], "h": h_last}
    return h, new_state


def attn_block(cfg, lp, h, positions, ctx: ShardCtx):
    a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    a_out, kv = attn.attention_train(cfg, a_in, lp, positions, ctx, window=cfg.local_window)
    h = h + a_out
    m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    h = h + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
    return h, kv


# --------------------------------------------------------------------------- #
# forward / loss / train                                                       #
# --------------------------------------------------------------------------- #
def _stack(cfg, params, h, positions, ctx):
    def group_body(carry, gp):
        hh = carry
        hh, _ = rg_block(cfg, gp["rg1"], hh, ctx)
        hh, _ = rg_block(cfg, gp["rg2"], hh, ctx)
        hh, _ = attn_block(cfg, gp["attn"], hh, positions, ctx)
        return hh, None

    def rest_body(carry, lp):
        hh, _ = rg_block(cfg, lp, carry, ctx)
        return hh, None

    g, r = _counts(cfg)
    if g:
        h, _ = jax.lax.scan(tf._remat(cfg, group_body), h, params["groups"])
    if r:
        h, _ = jax.lax.scan(tf._remat(cfg, rest_body), h, params["rest"])
    return h


def forward(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = _stack(cfg, params, h, positions, ctx)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_logits(h, params["out_head"], cfg.vocab_size, ctx)


def loss_fn(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    logits = forward(cfg, params, batch, ctx)
    loss = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss}


def make_train_step(cfg, optimizer, ctx: ShardCtx = NULL_CTX):
    return tf.make_train_step(cfg, optimizer, ctx, loss=loss_fn)


# --------------------------------------------------------------------------- #
# serving                                                                      #
# --------------------------------------------------------------------------- #
def _rg_state_shapes(cfg, L, batch):
    w = cfg.d_model
    dt = dtype_of(cfg)
    shapes = {"conv": SDS((L, batch, CONV_W - 1, w), dt), "h": SDS((L, batch, w), dt)}
    logical = {"conv": "layers batch . lru", "h": "layers batch lru"}
    return shapes, logical


def cache_shapes(cfg, batch: int, seq_len: int):
    g, r = _counts(cfg)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    win = min(cfg.local_window, seq_len)
    dt = dtype_of(cfg)
    rg_s, rg_l = _rg_state_shapes(cfg, g, batch)
    rest_s, rest_l = _rg_state_shapes(cfg, r, batch)
    shapes = {
        "groups": {
            "rg1": rg_s,
            "rg2": rg_s,
            "attn_k": SDS((g, batch, win, kv, hd), dt),
            "attn_v": SDS((g, batch, win, kv, hd), dt),
        },
        "rest": rest_s,
        "lengths": SDS((batch,), jnp.int32),
    }
    logical = {
        "groups": {
            "rg1": rg_l,
            "rg2": rg_l,
            "attn_k": "layers batch cache_seq kv_heads .",
            "attn_v": "layers batch cache_seq kv_heads .",
        },
        "rest": rest_l,
        "lengths": "batch",
    }
    return shapes, logical


def prefill(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    w = cfg.d_model
    win = min(cfg.local_window, s)
    zero_state = {
        "conv": jnp.zeros((b, CONV_W - 1, w), h.dtype),
        "h": jnp.zeros((b, w), h.dtype),
    }

    def ring_align(k):
        keep = k[:, -win:]
        shift = s % cfg.local_window if s >= cfg.local_window else 0
        return jnp.roll(keep, shift, axis=1)

    def group_body(carry, gp):
        hh = carry
        hh, st1 = rg_block(cfg, gp["rg1"], hh, ctx, zero_state)
        hh, st2 = rg_block(cfg, gp["rg2"], hh, ctx, zero_state)
        hh, (k, v) = attn_block(cfg, gp["attn"], hh, positions, ctx)
        return hh, {"rg1": st1, "rg2": st2,
                    "attn_k": ring_align(k), "attn_v": ring_align(v)}

    def rest_body(carry, lp):
        hh, st = rg_block(cfg, lp, carry, ctx, zero_state)
        return hh, st

    g, r = _counts(cfg)
    cache = {"lengths": jnp.full((b,), s, jnp.int32)}
    if g:
        h, gcache = jax.lax.scan(tf._remat(cfg, group_body), h, params["groups"])
        cache["groups"] = gcache
    if r:
        h, rcache = jax.lax.scan(tf._remat(cfg, rest_body), h, params["rest"])
        cache["rest"] = rcache
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h[:, -1:], params["out_head"], cfg.vocab_size, ctx)[:, 0]
    return cache, logits


def _rg_decode(cfg, lp, h, state, ctx):
    """Single-token rg_block (seq len 1) reusing the train path with state."""
    return rg_block(cfg, lp, h, ctx, state)


def decode_step(cfg, params, cache, batch, ctx: ShardCtx = NULL_CTX):
    token = batch["token"]
    h = embed_tokens(token[:, None], params["embed"], ctx)
    lengths = cache["lengths"]

    def group_body(carry, xs):
        hh = carry
        gp, gc = xs
        hh, st1 = _rg_decode(cfg, gp["rg1"], hh, gc["rg1"], ctx)
        hh, st2 = _rg_decode(cfg, gp["rg2"], hh, gc["rg2"], ctx)
        a_in = rms_norm(hh, gp["attn"]["attn_norm"], cfg.norm_eps)
        a_out, nk, nv = attn.decode_attention_block(
            cfg, a_in, gp["attn"], gc["attn_k"], gc["attn_v"], lengths, ctx,
            window=gc["attn_k"].shape[1],
        )
        hh = hh + a_out
        m_in = rms_norm(hh, gp["attn"]["mlp_norm"], cfg.norm_eps)
        hh = hh + swiglu_mlp(
            m_in, gp["attn"]["w_gate"], gp["attn"]["w_up"], gp["attn"]["w_down"], ctx
        )
        return hh, {"rg1": st1, "rg2": st2, "attn_k": nk, "attn_v": nv}

    def rest_body(carry, xs):
        lp, st = xs
        hh, nst = _rg_decode(cfg, lp, carry, st, ctx)
        return hh, nst

    g, r = _counts(cfg)
    new_cache = {"lengths": lengths + 1}
    if g:
        h, gcache = jax.lax.scan(group_body, h, (params["groups"], cache["groups"]))
        new_cache["groups"] = gcache
    if r:
        h, rcache = jax.lax.scan(rest_body, h, (params["rest"], cache["rest"]))
        new_cache["rest"] = rcache
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h, params["out_head"], cfg.vocab_size, ctx)[:, 0]
    return new_cache, logits


# --------------------------------------------------------------------------- #
# dry-run plumbing                                                             #
# --------------------------------------------------------------------------- #
input_specs = tf.input_specs


def roofline_units(cfg):
    g, r = _counts(cfg)
    base = dataclasses.replace(cfg, num_layers=r, attention_unroll=True)
    unit = dataclasses.replace(cfg, num_layers=r + 3, attention_unroll=True)
    return base, [(g, unit)]
