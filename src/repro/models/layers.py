"""Shared building blocks: RMSNorm, RoPE, SwiGLU MLP, embeddings, ShardCtx.

All model math runs in ``cfg.dtype`` with fp32 norms/softmax; every function
takes an explicit ``ShardCtx`` (mesh + logical rules) so the same code path
works on a single CPU device (ctx.mesh None -> no constraints, no shard_map)
and on the 512-chip production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, constrain, axis_size


@dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[object] = None   # jax.sharding.Mesh
    rules: Optional[Rules] = None

    def constrain(self, x, logical: str):
        if self.mesh is None or self.rules is None:
            return x
        return constrain(x, logical, self.rules, self.mesh)

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return axis_size(self.mesh, name)


NULL_CTX = ShardCtx()


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_mlp(x, w_gate, w_up, w_down, ctx: ShardCtx):
    """(B, S, D) -> (B, S, D); d_ff TP-sharded."""
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = ctx.constrain(h, "batch seq d_ff")
    out = jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))
    return ctx.constrain(out, "batch seq d_model")


def embed_tokens(tokens, embed, ctx: ShardCtx):
    out = jnp.take(embed, tokens, axis=0)
    return ctx.constrain(out, "batch seq d_model")


def lm_logits(h, out_head, vocab_size: int, ctx: ShardCtx):
    """Project to (padded) vocab and mask pad logits to -inf (exact loss)."""
    logits = jnp.einsum("bsd,dv->bsv", h, out_head.astype(h.dtype))
    logits = ctx.constrain(logits, "batch seq vocab")
    vp = out_head.shape[-1]
    if vp != vocab_size:
        pad_mask = jnp.arange(vp) >= vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e9, logits)
    return logits


def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross-entropy. logits (B,S,V) fp-any, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ------------------------------ init helpers ------------------------------ #
def trunc_normal(key, shape, std, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def split_tree(key, n):
    return list(jax.random.split(key, n))
