"""Dense GQA decoder LM + the generic stack machinery reused by moe/vlm.

Scan-over-layers everywhere (keeps HLO size O(1) in depth — required for
512-device CPU-backend compiles), configurable remat, uniform family API:

  param_shapes / param_logical / init_params / loss_fn / train_step /
  prefill / decode_step / input_specs / cache_shapes / param_count /
  roofline_units
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, named_sharding
from repro.models import attention as attn
from repro.models.layers import (
    NULL_CTX,
    ShardCtx,
    dtype_of,
    embed_tokens,
    lm_logits,
    rms_norm,
    rope,
    softmax_xent,
    swiglu_mlp,
    trunc_normal,
)

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------- #
# parameter schema (dense)                                                     #
# --------------------------------------------------------------------------- #
def layer_param_shapes(cfg) -> Dict[str, SDS]:
    d, h, kv, hd, f = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_ff
    L = cfg.num_layers
    dt = dtype_of(cfg)
    return {
        "attn_norm": SDS((L, d), dt),
        "wq": SDS((L, d, h, hd), dt),
        "wk": SDS((L, d, kv, hd), dt),
        "wv": SDS((L, d, kv, hd), dt),
        "wo": SDS((L, h, hd, d), dt),
        "mlp_norm": SDS((L, d), dt),
        "w_gate": SDS((L, d, f), dt),
        "w_up": SDS((L, d, f), dt),
        "w_down": SDS((L, f, d), dt),
    }


PRODUCTION_MODEL_AXIS = 16  # launch/mesh.py production mesh


def layer_param_logical(cfg) -> Dict[str, str]:
    # Archs whose head count doesn't divide the model axis (arctic/llava 56,
    # whisper 12, smollm 9) would REPLICATE their attention projections —
    # GBs per chip at serve. Shard them on the feature dim instead
    # ("attn_dw": data at train [= FSDP, unchanged], model at serve).
    div = cfg.num_heads % PRODUCTION_MODEL_AXIS == 0
    adw = "d_model_w" if div else "attn_dw"
    return {
        "attn_norm": "layers .",
        "wq": f"layers {adw} heads .",
        "wk": f"layers {adw} kv_heads .",
        "wv": f"layers {adw} kv_heads .",
        "wo": f"layers heads . {adw}",
        "mlp_norm": "layers .",
        "w_gate": "layers d_model_w d_ff",
        "w_up": "layers d_model_w d_ff",
        "w_down": "layers d_ff d_model_w",
    }


def param_shapes(cfg) -> Dict:
    d, vp = cfg.d_model, cfg.vocab_padded
    dt = dtype_of(cfg)
    out = {
        "embed": SDS((vp, d), dt),
        "final_norm": SDS((d,), dt),
        "layers": layer_param_shapes(cfg),
    }
    if not cfg.tie_embeddings:
        out["out_head"] = SDS((d, vp), dt)
    if cfg.family == "vlm":
        out["vision_proj"] = SDS((VISION_FEAT_DIM, d), dt)
    return out


def param_logical(cfg) -> Dict:
    out = {
        "embed": "vocab d_model_w",
        "final_norm": ".",
        "layers": layer_param_logical(cfg),
    }
    if not cfg.tie_embeddings:
        out["out_head"] = "d_model_w vocab"
    if cfg.family == "vlm":
        out["vision_proj"] = ". d_model_w"
    return out


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    std = 0.02

    def mk(k, sds):
        if sds.shape and len(sds.shape) >= 2:
            return trunc_normal(k, sds.shape, std, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, flat)])


def param_count(cfg) -> int:
    shapes = param_shapes(cfg)
    import math

    return sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))


def active_param_count(cfg) -> int:
    return param_count(cfg)


VISION_FEAT_DIM = 1024  # stub frontend feature width (llava patch embeddings)


# --------------------------------------------------------------------------- #
# forward                                                                      #
# --------------------------------------------------------------------------- #
def sp_constrain(cfg, h, ctx: ShardCtx):
    """Megatron-SP (§Perf): inter-block activations shard SEQ over 'model'
    — 16x smaller residual-stream footprint, so grad accumulation (and its
    per-microbatch FSDP regathers) becomes unnecessary. GSPMD converts the
    TP all-reduces at block boundaries into all-gather/reduce-scatter pairs
    of the same total bytes."""
    if getattr(cfg, "seq_parallel", False):
        return ctx.constrain(h, "batch seq_sp d_model")
    return h


def dense_block(cfg, lp, h, positions, ctx: ShardCtx):
    a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    a_out, _ = attn.attention_train(
        cfg, a_in, lp, positions, ctx, window=cfg.sliding_window
    )
    h = sp_constrain(cfg, h + a_out, ctx)
    m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    h = h + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
    return sp_constrain(cfg, h, ctx)


def _remat(cfg, fn):
    if not cfg.remat:
        return fn
    policy = getattr(jax.checkpoint_policies, "nothing_saveable")
    name = getattr(cfg, "remat_policy", "nothing")
    if name == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    elif name == "dots_no_batch":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


def stack_forward(cfg, params, h, positions, ctx: ShardCtx, block_fn=dense_block):
    def body(carry, lp):
        return block_fn(cfg, lp, carry, positions, ctx), None

    h, _ = jax.lax.scan(_remat(cfg, body), h, params["layers"])
    return h


def embed_input(cfg, params, batch, ctx: ShardCtx):
    """Token (+ optional patch) embedding. Returns (h, positions, loss_mask)."""
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    if cfg.family == "vlm":
        patches = batch["patches"].astype(h.dtype)  # (B, P, VISION_FEAT_DIM)
        pe = jnp.einsum("bpf,fd->bpd", patches, params["vision_proj"].astype(h.dtype))
        pe = ctx.constrain(pe, "batch seq d_model")
        h = jnp.concatenate([pe, h], axis=1)
        s = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return h, positions


def forward(cfg, params, batch, ctx: ShardCtx = NULL_CTX, block_fn=dense_block):
    h, positions = embed_input(cfg, params, batch, ctx)
    h = stack_forward(cfg, params, h, positions, ctx, block_fn)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    return lm_logits(h, head, cfg.vocab_size, ctx)


def loss_fn(cfg, params, batch, ctx: ShardCtx = NULL_CTX, block_fn=dense_block):
    logits = forward(cfg, params, batch, ctx, block_fn)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if cfg.family == "vlm":
        # image patch positions carry no next-token loss
        p = cfg.num_patches
        logits = logits[:, p:]
    loss = softmax_xent(logits, labels, mask)
    return loss, {"loss": loss}


def make_train_step(cfg, optimizer, ctx: ShardCtx = NULL_CTX, block_fn=dense_block,
                    loss=None):
    """Returns train_step(params, opt_state, batch).

    cfg.grad_accum > 1 runs gradient-accumulation microbatching: the global
    batch is split on its leading dim and scanned, so per-microbatch
    activations (and the per-layer remat carries) shrink by the accumulation
    factor — this is what fits the 100B+ archs on a 256-chip pod.
    """
    loss = loss or partial(loss_fn, block_fn=block_fn)
    accum = max(1, getattr(cfg, "grad_accum", 1))
    acc_dt = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))

    def _grad(params, batch):
        return jax.value_and_grad(
            lambda p: loss(cfg, p, batch, ctx), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if accum <= 1:
            (l, metrics), grads = _grad(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch,
            )
            micro = jax.tree.map(
                lambda x: ctx.constrain(x, ". batch" + " ." * (x.ndim - 2)), micro
            )

            def mb(carry, mbatch):
                gsum, lsum = carry
                (l, _m), g = _grad(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), gsum, g
                )
                return (gsum, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            l = lsum / accum
            metrics = {"loss": l}
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = optimizer.global_norm(grads)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------------------- #
# serving                                                                      #
# --------------------------------------------------------------------------- #
def cache_len(cfg, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def cache_dtype_of(cfg):
    """KV-cache storage dtype (§Perf: fp8 cache halves decode cache reads;
    the attend path upcasts, so it is a storage-only change)."""
    cd = getattr(cfg, "cache_dtype", "")
    return jnp.dtype(cd) if cd else dtype_of(cfg)


def cache_shapes(cfg, batch: int, seq_len: int):
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    s = cache_len(cfg, seq_len)
    dt = cache_dtype_of(cfg)
    shapes = {
        "k": SDS((L, batch, s, kv, hd), dt),
        "v": SDS((L, batch, s, kv, hd), dt),
        "lengths": SDS((batch,), jnp.int32),
    }
    logical = {
        "k": "layers batch cache_seq kv_heads .",
        "v": "layers batch cache_seq kv_heads .",
        "lengths": "batch",
    }
    return shapes, logical


def prefill(cfg, params, batch, ctx: ShardCtx = NULL_CTX, block_fn=dense_block,
            pad_cache_to: int | None = None):
    """Run the full prompt; returns (cache, last-position logits).

    ``pad_cache_to`` reserves decode headroom: the returned cache's seq dim
    is padded to that length (ring-buffer SWA caches are fixed-size and
    ignore it)."""
    h, positions = embed_input(cfg, params, batch, ctx)
    w = cfg.sliding_window

    def body(carry, lp):
        hh = carry
        a_in = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        a_out, (k, v) = attn.attention_train(cfg, a_in, lp, positions, ctx, window=w)
        hh = hh + a_out
        m_in = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
        if w:
            # ring-buffer layout: slot = position % window
            s = k.shape[1]
            keep = min(w, s)
            k = k[:, -keep:]
            v = v[:, -keep:]
            shift = s % w if s >= w else 0
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
        k = ctx.constrain(k.astype(cache_dtype_of(cfg)), "batch cache_seq kv_heads .")
        v = ctx.constrain(v.astype(cache_dtype_of(cfg)), "batch cache_seq kv_heads .")
        return hh, (k, v)

    h, (ks, vs) = jax.lax.scan(_remat(cfg, body), h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    logits = lm_logits(h[:, -1:], head, cfg.vocab_size, ctx)[:, 0]
    b, s = h.shape[0], h.shape[1]
    if pad_cache_to is not None and not w and pad_cache_to > ks.shape[2]:
        pad = pad_cache_to - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": ks,
        "v": vs,
        "lengths": jnp.full((b,), s, jnp.int32),
    }
    return cache, logits


def decode_step(cfg, params, cache, batch, ctx: ShardCtx = NULL_CTX,
                mlp_fn=None):
    """One token for every sequence. batch: {"token": (B,) int32}."""
    token = batch["token"]
    b = token.shape[0]
    h = embed_tokens(token[:, None], params["embed"], ctx)  # (B, 1, D)
    lengths = cache["lengths"]
    w = cfg.sliding_window

    def body(carry, xs):
        hh = carry
        lp, ck, cv = xs
        a_in = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        a_out, nk, nv = attn.decode_attention_block(
            cfg, a_in, lp, ck, cv, lengths, ctx, window=w
        )
        hh = hh + a_out
        m_in = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        if mlp_fn is None:
            hh = hh + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
        else:
            hh = hh + mlp_fn(cfg, lp, m_in, ctx)
        return hh, (nk, nv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    logits = lm_logits(h, head, cfg.vocab_size, ctx)[:, 0]
    new_cache = {"k": ks, "v": vs, "lengths": lengths + 1}
    return new_cache, logits


# --------------------------------------------------------------------------- #
# dry-run plumbing                                                             #
# --------------------------------------------------------------------------- #
def input_specs(cfg, shape, mesh=None, rules: Rules | None = None) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell."""
    b, s = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)

    def sh(shp, logical, dtype):
        if mesh is None or rules is None:
            return SDS(shp, dtype)
        return SDS(shp, dtype, sharding=named_sharding(shp, logical, rules, mesh))

    if shape.kind == "decode":
        return {"token": sh((b,), "batch", jnp.int32)}
    text = s
    out = {}
    if cfg.family == "vlm":
        text = s - cfg.num_patches
        out["patches"] = sh((b, cfg.num_patches, VISION_FEAT_DIM), "batch patches .", dt)
    out["tokens"] = sh((b, text), "batch seq", jnp.int32)
    if shape.kind == "train":
        out["labels"] = sh((b, text), "batch seq", jnp.int32)
    return out


def roofline_units(cfg):
    """(base_cfg, [(count, unit_cfg)]): cost(cfg) = cost(base) + sum count*(cost(unit)-cost(base)).

    Unit configs unroll the attention q-chunking so XLA counts every chunk
    (map bodies are counted once by cost_analysis — calibrated)."""
    base = dataclasses.replace(cfg, num_layers=0, attention_unroll=True)
    unit = dataclasses.replace(cfg, num_layers=1, attention_unroll=True)
    return base, [(cfg.num_layers, unit)]
