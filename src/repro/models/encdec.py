"""Encoder-decoder family (whisper-small).

The conv/mel frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, num_frames, d_model). Encoder = bidirectional attention
blocks; decoder = causal self-attention + cross-attention blocks. Decode
shapes exercise the decoder with a self-attn KV cache at the requested
length plus fixed cross-attention K/V over the encoded frames. RoPE stands
in for the original learned positional embeddings (noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import Rules, named_sharding
from repro.models import attention as attn
from repro.models import transformer as tf
from repro.models.layers import (
    NULL_CTX, ShardCtx, dtype_of, embed_tokens, lm_logits, rms_norm,
    softmax_xent, swiglu_mlp, trunc_normal,
)

SDS = jax.ShapeDtypeStruct


# --------------------------------------------------------------------------- #
# parameters                                                                   #
# --------------------------------------------------------------------------- #
def _enc_layer_shapes(cfg, L):
    return tf.layer_param_shapes(dataclasses.replace(cfg, num_layers=L))


def _dec_layer_shapes(cfg, L):
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    dt = dtype_of(cfg)
    shapes = tf.layer_param_shapes(dataclasses.replace(cfg, num_layers=L))
    shapes.update(
        {
            "xattn_norm": SDS((L, d), dt),
            "xwq": SDS((L, d, h, hd), dt),
            "xwk": SDS((L, d, cfg.num_kv_heads, hd), dt),
            "xwv": SDS((L, d, cfg.num_kv_heads, hd), dt),
            "xwo": SDS((L, h, hd, d), dt),
        }
    )
    return shapes


def _dec_layer_logical(cfg):
    logical = tf.layer_param_logical(cfg)
    div = cfg.num_heads % tf.PRODUCTION_MODEL_AXIS == 0
    adw = "d_model_w" if div else "attn_dw"
    logical.update(
        {
            "xattn_norm": "layers .",
            "xwq": f"layers {adw} heads .",
            "xwk": f"layers {adw} kv_heads .",
            "xwv": f"layers {adw} kv_heads .",
            "xwo": f"layers heads . {adw}",
        }
    )
    return logical


def param_shapes(cfg) -> Dict:
    d, vp = cfg.d_model, cfg.vocab_padded
    dt = dtype_of(cfg)
    return {
        "embed": SDS((vp, d), dt),
        "out_head": SDS((d, vp), dt),
        "final_norm": SDS((d,), dt),
        "enc_final_norm": SDS((d,), dt),
        "enc_layers": _enc_layer_shapes(cfg, cfg.num_encoder_layers),
        "dec_layers": _dec_layer_shapes(cfg, cfg.num_layers),
    }


def param_logical(cfg) -> Dict:
    return {
        "embed": "vocab d_model_w",
        "out_head": "d_model_w vocab",
        "final_norm": ".",
        "enc_final_norm": ".",
        "enc_layers": tf.layer_param_logical(cfg),
        "dec_layers": _dec_layer_logical(cfg),
    }


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))

    def mk(k, sds):
        if sds.shape and len(sds.shape) >= 2:
            return trunc_normal(k, sds.shape, 0.02, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, flat)])


def param_count(cfg) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg) -> int:
    return param_count(cfg)


# --------------------------------------------------------------------------- #
# forward                                                                      #
# --------------------------------------------------------------------------- #
def encode(cfg, params, frames, ctx: ShardCtx):
    """frames: (B, F, D) stub embeddings -> encoder output (B, F, D)."""
    h = frames
    b, f = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    def body(carry, lp):
        hh = carry
        a_in = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        a_out, _ = attn.attention_train(cfg, a_in, lp, positions, ctx, causal=False)
        hh = hh + a_out
        m_in = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
        return hh, None

    h, _ = jax.lax.scan(tf._remat(cfg, body), h, params["enc_layers"])
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def _dec_block(cfg, lp, h, positions, enc_kv, ctx: ShardCtx):
    ek, ev = enc_kv
    a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    a_out, kv = attn.attention_train(cfg, a_in, lp, positions, ctx)
    h = h + a_out
    x_in = rms_norm(h, lp["xattn_norm"], cfg.norm_eps)
    h = h + attn.cross_attention(cfg, x_in, lp, ek, ev, ctx)
    m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    h = h + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
    return h, kv


def _cross_kv(cfg, lp, enc_out, ctx: ShardCtx):
    dt = enc_out.dtype
    ek = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["xwk"].astype(dt))
    ev = jnp.einsum("bfd,dhk->bfhk", enc_out, lp["xwv"].astype(dt))
    ek = ctx.constrain(ek, "batch frames kv_heads .")
    ev = ctx.constrain(ev, "batch frames kv_heads .")
    return ek, ev


def forward(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    enc_out = encode(cfg, params, batch["frames"].astype(dtype_of(cfg)), ctx)
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        enc_kv = _cross_kv(cfg, lp, enc_out, ctx)
        hh, _ = _dec_block(cfg, lp, carry, positions, enc_kv, ctx)
        return hh, None

    h, _ = jax.lax.scan(tf._remat(cfg, body), h, params["dec_layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_logits(h, params["out_head"], cfg.vocab_size, ctx)


def loss_fn(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    logits = forward(cfg, params, batch, ctx)
    loss = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"loss": loss}


def make_train_step(cfg, optimizer, ctx: ShardCtx = NULL_CTX):
    return tf.make_train_step(cfg, optimizer, ctx, loss=loss_fn)


# --------------------------------------------------------------------------- #
# serving                                                                      #
# --------------------------------------------------------------------------- #
def cache_shapes(cfg, batch: int, seq_len: int):
    L, kv, hd, f = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, cfg.num_frames
    dt = dtype_of(cfg)
    shapes = {
        "k": SDS((L, batch, seq_len, kv, hd), dt),
        "v": SDS((L, batch, seq_len, kv, hd), dt),
        "cross_k": SDS((L, batch, f, kv, hd), dt),
        "cross_v": SDS((L, batch, f, kv, hd), dt),
        "lengths": SDS((batch,), jnp.int32),
    }
    logical = {
        "k": "layers batch cache_seq kv_heads .",
        "v": "layers batch cache_seq kv_heads .",
        "cross_k": "layers batch frames kv_heads .",
        "cross_v": "layers batch frames kv_heads .",
        "lengths": "batch",
    }
    return shapes, logical


def prefill(cfg, params, batch, ctx: ShardCtx = NULL_CTX, pad_cache_to=None):
    enc_out = encode(cfg, params, batch["frames"].astype(dtype_of(cfg)), ctx)
    tokens = batch["tokens"]
    h = embed_tokens(tokens, params["embed"], ctx)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        enc_kv = _cross_kv(cfg, lp, enc_out, ctx)
        hh, kv = _dec_block(cfg, lp, carry, positions, enc_kv, ctx)
        return hh, (kv[0], kv[1], enc_kv[0], enc_kv[1])

    h, (ks, vs, eks, evs) = jax.lax.scan(tf._remat(cfg, body), h, params["dec_layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h[:, -1:], params["out_head"], cfg.vocab_size, ctx)[:, 0]
    if pad_cache_to is not None and pad_cache_to > ks.shape[2]:
        pad = pad_cache_to - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": ks, "v": vs, "cross_k": eks, "cross_v": evs,
        "lengths": jnp.full((b,), s, jnp.int32),
    }
    return cache, logits


def decode_step(cfg, params, cache, batch, ctx: ShardCtx = NULL_CTX):
    token = batch["token"]
    h = embed_tokens(token[:, None], params["embed"], ctx)
    lengths = cache["lengths"]

    def body(carry, xs):
        hh = carry
        lp, ck, cv, ek, ev = xs
        a_in = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        a_out, nk, nv = attn.decode_attention_block(cfg, a_in, lp, ck, cv, lengths, ctx)
        hh = hh + a_out
        x_in = rms_norm(hh, lp["xattn_norm"], cfg.norm_eps)
        hh = hh + attn.cross_attention(cfg, x_in, lp, ek, ev, ctx)
        m_in = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
        return hh, (nk, nv)

    h, (ks, vs) = jax.lax.scan(
        body, h,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(h, params["out_head"], cfg.vocab_size, ctx)[:, 0]
    new_cache = dict(cache, k=ks, v=vs, lengths=lengths + 1)
    return new_cache, logits


# --------------------------------------------------------------------------- #
# dry-run plumbing                                                             #
# --------------------------------------------------------------------------- #
def input_specs(cfg, shape, mesh=None, rules: Rules | None = None) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg)

    def sh(shp, logical, dtype):
        if mesh is None or rules is None:
            return SDS(shp, dtype)
        return SDS(shp, dtype, sharding=named_sharding(shp, logical, rules, mesh))

    if shape.kind == "decode":
        return {"token": sh((b,), "batch", jnp.int32)}
    out = {
        "frames": sh((b, cfg.num_frames, cfg.d_model), "batch frames d_model", dt),
        "tokens": sh((b, s), "batch seq", jnp.int32),
    }
    if shape.kind == "train":
        out["labels"] = sh((b, s), "batch seq", jnp.int32)
    return out


def roofline_units(cfg):
    base = dataclasses.replace(cfg, num_layers=0, num_encoder_layers=0,
                               attention_unroll=True)
    enc1 = dataclasses.replace(cfg, num_layers=0, num_encoder_layers=1,
                               attention_unroll=True)
    dec1 = dataclasses.replace(cfg, num_layers=1, num_encoder_layers=0,
                               attention_unroll=True)
    return base, [(cfg.num_encoder_layers, enc1), (cfg.num_layers, dec1)]
