"""Family registry: maps ModelConfig.family -> implementation module.

Every module satisfies the uniform API:
  param_shapes, param_logical, init_params, param_count, active_param_count,
  loss_fn, make_train_step, prefill, decode_step, input_specs, cache_shapes,
  roofline_units
"""
from __future__ import annotations

from types import ModuleType


def family_module(family: str) -> ModuleType:
    from repro.models import encdec, hybrid, moe, ssm, transformer, vlm

    table = {
        "dense": transformer,
        "moe": moe,
        "encdec": encdec,
        "hybrid": hybrid,
        "ssm": ssm,
        "vlm": vlm,
    }
    if family not in table:
        raise KeyError(f"unknown family {family!r}")
    return table[family]


def model_api(cfg) -> ModuleType:
    return family_module(cfg.family)
