"""MoE decoder family (arctic-480b, grok-1-314b).

Dispatch design (DESIGN.md §5): activations are TP-replicated across the
"model" axis, so expert dispatch needs NO all-to-all — a shard_map over
"model" lets each shard gather the (capacity-bounded) tokens routed to its
local experts, compute, scatter-add, and contribute through the same psum a
dense TP MLP needs anyway. Two layouts fall out of the sharding rules
automatically:

  * EP  (arctic: 128 experts % 16 == 0): expert dim sharded -> each shard
    owns E/16 experts fully.
  * TP  (grok: 8 experts < 16-way axis): experts replicated, d_ff sharded ->
    each shard computes ALL experts on its f-slice; psum sums the partials.

Routing is the fused top-k kernel's math (kernels/moe_router.py; ref path
inside the shard_map so XLA cost analysis sees the FLOPs). A switch-style
load-balancing aux loss is added to the task loss.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as kref
from repro.kernels.launch import shard_map
from repro.models import transformer as tf
from repro.models.layers import NULL_CTX, ShardCtx, dtype_of, rms_norm, swiglu_mlp
from repro.distributed.sharding import spec_for

SDS = jax.ShapeDtypeStruct

AUX_LOSS_COEF = 0.01


# --------------------------------------------------------------------------- #
# parameters                                                                   #
# --------------------------------------------------------------------------- #
def layer_param_shapes(cfg) -> Dict[str, SDS]:
    shapes = tf.layer_param_shapes(cfg)
    L, d, f, e = cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = dtype_of(cfg)
    shapes.update(
        {
            "router": SDS((L, d, e), dt),
            "e_gate": SDS((L, e, d, f), dt),
            "e_up": SDS((L, e, d, f), dt),
            "e_down": SDS((L, e, f, d), dt),
        }
    )
    if not cfg.moe_dense_residual:
        # pure-MoE layers have no dense MLP
        for k in ("w_gate", "w_up", "w_down"):
            shapes.pop(k)
    return shapes


def layer_param_logical(cfg) -> Dict[str, str]:
    logical = tf.layer_param_logical(cfg)
    if getattr(cfg, "moe_serve_ep2d", False):
        # resident-expert serving layout: experts over 'data', d_ff over
        # 'model' — matches the ep2d shard_map in_specs EXACTLY so no
        # per-layer weight reshuffle is inserted (measured in SS Perf).
        logical.update(
            {
                "router": "layers d_model_w .",
                "e_gate": "layers experts_data . d_ff",
                "e_up": "layers experts_data . d_ff",
                "e_down": "layers experts_data d_ff .",
            }
        )
    else:
        logical.update(
            {
                # expert_dw shards over "data" in BOTH train (FSDP) and
                # serve rules: 480B of experts cannot be data-replicated at
                # serve; shard_map in_specs gather them per layer (moe_ffn).
                "router": "layers d_model_w .",
                "e_gate": "layers experts expert_dw d_ff",
                "e_up": "layers experts expert_dw d_ff",
                "e_down": "layers experts d_ff expert_dw",
            }
        )
    if not cfg.moe_dense_residual:
        for k in ("w_gate", "w_up", "w_down"):
            logical.pop(k)
    return logical


def param_shapes(cfg):
    out = tf.param_shapes(cfg)
    out["layers"] = layer_param_shapes(cfg)
    return out


def param_logical(cfg):
    out = tf.param_logical(cfg)
    out["layers"] = layer_param_logical(cfg)
    return out


input_specs = tf.input_specs
roofline_units = tf.roofline_units


def init_params(cfg, key):
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(flat))
    from repro.models.layers import trunc_normal

    def mk(k, sds):
        if sds.shape and len(sds.shape) >= 2:
            return trunc_normal(k, sds.shape, 0.02, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree.unflatten(treedef, [mk(k, s) for k, s in zip(keys, flat)])


def param_count(cfg) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(param_shapes(cfg)))


def active_param_count(cfg) -> int:
    """6*N_active*D accounting: experts count k/E of their params."""
    total = param_count(cfg)
    L, e, d, f = cfg.num_layers, cfg.num_experts, cfg.d_model, cfg.d_ff
    expert_params = L * e * 3 * d * f
    active_expert = L * cfg.num_experts_per_tok * 3 * d * f
    return total - expert_params + active_expert


# --------------------------------------------------------------------------- #
# MoE FFN                                                                      #
# --------------------------------------------------------------------------- #
def _capacity(cfg, tokens: int) -> int:
    c = math.ceil(cfg.num_experts_per_tok * tokens / cfg.num_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)


def _moe_local(x, router_w, wg, wu, wd, *, cfg, capacity, axis, ep: bool,
               expert_axis=None):
    """Per-shard MoE computation. x: (B_loc, S, D) replicated over `axis`.

    ``expert_axis``: mesh axis the EXPERT dim is sharded over (defaults to
    ``axis``); psum runs over ``axis`` which may be a tuple (the ep2d
    resident-expert layout psums over both 'data' and 'model')."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(t, d)

    logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)  # (T, E)
    weights, idx = kref.moe_topk_router(logits, k)

    # switch-style load-balance aux: E * sum(mean_prob_e * frac_tokens_e)
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # rank of each assignment within its expert
    flat_e = idx.reshape(-1)                             # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.astype(jnp.float32).reshape(-1)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    order = jnp.argsort(flat_e, stable=True)
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[flat_e[order]]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < capacity
    slot = jnp.where(keep, flat_e * capacity + rank, e * capacity)
    tok_per_slot = (
        jnp.full((e * capacity + 1,), t, jnp.int32).at[slot].set(flat_t)[: e * capacity]
    ).reshape(e, capacity)
    w_per_slot = (
        jnp.zeros((e * capacity + 1,), jnp.float32).at[slot].set(flat_w)[: e * capacity]
    ).reshape(e, capacity)

    # local expert slice
    e_loc = wg.shape[0]
    if ep and axis is not None:
        e0 = jax.lax.axis_index(expert_axis or axis) * e_loc
        tok_loc = jax.lax.dynamic_slice_in_dim(tok_per_slot, e0, e_loc, 0)
        w_loc = jax.lax.dynamic_slice_in_dim(w_per_slot, e0, e_loc, 0)
    else:
        tok_loc, w_loc = tok_per_slot, w_per_slot

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[tok_loc]                                      # (E_loc, C, D)
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe.dtype))
    ye = ye * w_loc[..., None].astype(ye.dtype)

    y = (
        jnp.zeros((t + 1, d), ye.dtype)
        .at[tok_loc.reshape(-1)]
        .add(ye.reshape(-1, d))[:t]
    )
    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y.reshape(b, s, d), aux


def moe_ffn(cfg, lp, x, ctx: ShardCtx):
    """(B, S, D) -> ((B, S, D), aux_loss)."""
    e = cfg.num_experts
    model_size = ctx.axis_size("model")
    # capacity from the PER-DATA-SHARD token count (what each shard routes)
    dp = 1
    if ctx.mesh is not None:
        for a in ("pod", "data"):
            dp *= ctx.axis_size(a)
    b, s, _ = x.shape
    local_tokens = max(1, (b // max(dp, 1)) * s) if b >= dp else b * s
    capacity = _capacity(cfg, local_tokens)

    if ctx.mesh is None or model_size <= 1:
        return _moe_local(
            x, lp["router"], lp["e_gate"], lp["e_up"], lp["e_down"],
            cfg=cfg, capacity=capacity, axis=None, ep=False,
        )

    mesh = ctx.mesh
    rs = P(None, None)

    # ---- beyond-paper (§Perf): resident-expert 2D EP for small-token steps.
    # Experts shard over 'data' (128 % 16 == 0), d_ff over 'model': weights
    # are fully RESIDENT — no per-layer gather. Tokens replicate over the
    # mesh (cheap: decode moves B*D bytes, vs gathering GBs of weights);
    # disjoint expert contributions + partial-F products combine in one
    # psum over both axes.
    data_size = ctx.axis_size("data")
    tokens_global = b * s
    if (
        getattr(cfg, "moe_serve_ep2d", False)
        and data_size > 1
        and e % data_size == 0
        and tokens_global <= 4096
    ):
        cap2 = _capacity(cfg, tokens_global)
        fn = partial(_moe_local, cfg=cfg, capacity=cap2,
                     axis=("data", "model"), ep=True, expert_axis="data")
        y, aux = shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(None, None, None), rs,
                      P("data", None, "model"), P("data", None, "model"),
                      P("data", "model", None)),
            out_specs=(P(None, None, None), P()),
            check_vma=False,
        )(x, lp["router"], lp["e_gate"], lp["e_up"], lp["e_down"])
        return ctx.constrain(y, "batch seq d_model"), aux

    ep = e % model_size == 0
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
    xs = P(bspec, None, None)
    if ep:
        ws_gu = P("model", None, None)
        ws_d = P("model", None, None)
    else:
        ws_gu = P(None, None, "model")
        ws_d = P(None, "model", None)
    fn = partial(_moe_local, cfg=cfg, capacity=capacity, axis="model", ep=ep)
    y, aux = shard_map(
        fn,
        mesh=mesh,
        in_specs=(xs, rs, ws_gu, ws_gu, ws_d),
        out_specs=(xs, P()),
        check_vma=False,
    )(x, lp["router"], lp["e_gate"], lp["e_up"], lp["e_down"])
    return y, aux


# --------------------------------------------------------------------------- #
# blocks / steps                                                               #
# --------------------------------------------------------------------------- #
def moe_block(cfg, lp, h, positions, ctx: ShardCtx, aux_acc=None):
    from repro.models import attention as attn

    a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    a_out, _ = attn.attention_train(cfg, a_in, lp, positions, ctx,
                                    window=cfg.sliding_window)
    h = tf.sp_constrain(cfg, h + a_out, ctx)
    m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    y, aux = moe_ffn(cfg, lp, m_in, ctx)
    if cfg.moe_dense_residual:
        y = y + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
    return tf.sp_constrain(cfg, h + y, ctx), aux


def forward(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    h, positions = tf.embed_input(cfg, params, batch, ctx)

    def body(carry, lp):
        hh, aux_sum = carry
        hh, aux = moe_block(cfg, lp, hh, positions, ctx)
        return (hh, aux_sum + aux), None

    (h, aux_sum), _ = jax.lax.scan(
        tf._remat(cfg, body), (h, jnp.zeros((), jnp.float32)), params["layers"]
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    from repro.models.layers import lm_logits

    return lm_logits(h, head, cfg.vocab_size, ctx), aux_sum


def loss_fn(cfg, params, batch, ctx: ShardCtx = NULL_CTX):
    logits, aux = forward(cfg, params, batch, ctx)
    from repro.models.layers import softmax_xent

    task = softmax_xent(logits, batch["labels"], batch.get("loss_mask"))
    loss = task + AUX_LOSS_COEF * aux
    return loss, {"loss": task, "aux_loss": aux}


def make_train_step(cfg, optimizer, ctx: ShardCtx = NULL_CTX):
    return tf.make_train_step(cfg, optimizer, ctx, loss=loss_fn)


def _moe_mlp_fn(cfg, lp, m_in, ctx):
    y, _aux = moe_ffn(cfg, lp, m_in, ctx)
    if cfg.moe_dense_residual:
        y = y + swiglu_mlp(m_in, lp["w_gate"], lp["w_up"], lp["w_down"], ctx)
    return y


def prefill(cfg, params, batch, ctx: ShardCtx = NULL_CTX, pad_cache_to=None):
    from repro.models import attention as attn
    from repro.models.layers import lm_logits

    h, positions = tf.embed_input(cfg, params, batch, ctx)
    w = cfg.sliding_window

    def body(carry, lp):
        hh = carry
        a_in = rms_norm(hh, lp["attn_norm"], cfg.norm_eps)
        a_out, (k, v) = attn.attention_train(cfg, a_in, lp, positions, ctx, window=w)
        hh = hh + a_out
        m_in = rms_norm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + _moe_mlp_fn(cfg, lp, m_in, ctx)
        k = ctx.constrain(k, "batch cache_seq kv_heads .")
        v = ctx.constrain(v, "batch cache_seq kv_heads .")
        return hh, (k, v)

    h, (ks, vs) = jax.lax.scan(tf._remat(cfg, body), h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["out_head"]
    logits = lm_logits(h[:, -1:], head, cfg.vocab_size, ctx)[:, 0]
    if pad_cache_to is not None and not w and pad_cache_to > ks.shape[2]:
        pad = pad_cache_to - ks.shape[2]
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "lengths": jnp.full((h.shape[0],), h.shape[1], jnp.int32)}
    return cache, logits


def decode_step(cfg, params, cache, batch, ctx: ShardCtx = NULL_CTX):
    return tf.decode_step(cfg, params, cache, batch, ctx, mlp_fn=_moe_mlp_fn)


cache_shapes = tf.cache_shapes
