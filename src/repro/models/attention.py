"""GQA attention: training/prefill (flash), decode (sequence-sharded cache).

Decode design (the memory-optimal layout for 32k caches, see DESIGN.md §5):
the KV cache shards its SEQUENCE dim over the "model" mesh axis. A
``shard_map`` computes per-shard partial softmax stats (m, l, o) and combines
them with a psum rescale — mathematically exact flash-decode across shards.
The new token's K/V is written by the owning shard via a masked dynamic
update. This sidesteps the kv-head divisibility problem entirely (kv_heads in
{1,3,4,8,12} vs a 16-way axis) and keeps per-chip cache at
batch/data x seq/model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops, ref
from repro.kernels.launch import shard_map
from repro.models.layers import ShardCtx, rope


def qkv_proj(cfg, x, wq, wk, wv, ctx: ShardCtx):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, wq.astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, wk.astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, wv.astype(dt))
    q = ctx.constrain(q, "batch seq heads .")
    k = ctx.constrain(k, "batch seq kv_heads .")
    v = ctx.constrain(v, "batch seq kv_heads .")
    return q, k, v


def out_proj(x, wo, ctx: ShardCtx):
    out = jnp.einsum("bshk,hkd->bsd", x, wo.astype(x.dtype))
    # pin the einsum OUTPUT to the weight's d-sharding first: without this
    # the partitioner may choose the replicated-weights strategy and
    # all-gather wo (205 MB/layer, measured) instead of the 1.8 MB output
    out = ctx.constrain(out, "batch seq d_sharded")
    return ctx.constrain(out, "batch seq d_model")


def attention_train(
    cfg, x, lp, positions, ctx: ShardCtx, *, window: int = 0, causal: bool = True
):
    """Full training/prefill attention. lp: layer params dict with wq/wk/wv/wo."""
    q, k, v = qkv_proj(cfg, x, lp["wq"], lp["wk"], lp["wv"], ctx)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = ops.flash_attention(
        q, k, v, causal=causal, window=window, impl=cfg.attention_impl,
        chunk_q=getattr(cfg, "attention_chunk_q", 512),
        unroll=getattr(cfg, "attention_unroll", False),
    )
    return out_proj(o, lp["wo"], ctx), (k, v)


def cross_attention(cfg, x, lp, k, v, ctx: ShardCtx):
    """Decoder cross-attention over precomputed encoder K/V (no mask)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, lp["xwq"].astype(dt))
    q = ctx.constrain(q, "batch seq heads .")
    o = ops.flash_attention(q, k, v, causal=False, impl="xla")
    return out_proj(o, lp["xwo"], ctx)


# --------------------------------------------------------------------------- #
# decode with sequence-sharded KV cache                                        #
# --------------------------------------------------------------------------- #
def _local_decode(
    q, k_cache, v_cache, new_k, new_v, lengths, *, seq_per_shard, axis,
):
    """Body run per model-shard: update local cache slice, partial attention.

    q: (B, H, D); caches: (B, S_loc, Hkv, D); new_k/v: (B, Hkv, D);
    lengths: (B,) tokens already in cache (new token goes at this index).
    """
    sl = seq_per_shard
    offset = (jax.lax.axis_index(axis) * sl) if axis else 0
    local_idx = lengths - offset  # (B,) position of the new token locally

    def upd(c, nk, li):
        # row-wise select + ONE dynamic_update_slice: with the cache buffer
        # donated, XLA updates in place — a whole-array where() would force
        # a full cache copy per layer (measured in §Perf iteration 3).
        inb = (li >= 0) & (li < sl)
        lic = jnp.clip(li, 0, sl - 1)
        cur = jax.lax.dynamic_slice(c, (lic, 0, 0), (1,) + c.shape[1:])
        row = jnp.where(inb, nk[None].astype(c.dtype), cur)
        return jax.lax.dynamic_update_slice(c, row, (lic, 0, 0))

    k_cache = jax.vmap(upd)(k_cache, new_k, local_idx)
    v_cache = jax.vmap(upd)(v_cache, new_v, local_idx)

    # valid entries in THIS shard after the write
    local_len = jnp.clip(lengths + 1 - offset, 0, sl)

    out = _partial_softmax_attend(q, k_cache, v_cache, local_len, axis)
    return out, k_cache, v_cache


def _partial_softmax_attend(q, k_cache, v_cache, local_len, axis):
    """Grouped-head partial attention WITHOUT materializing expanded KV.

    q (B,H,D), caches (B,S,Hkv,D): contract per kv-head group so the cache
    is read ONCE at its stored width (bf16/fp8 — no f32 copy in HBM);
    f32 happens in the MXU accumulator via preferred_element_type.
    """
    b, h, d = q.shape
    sl, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    kc = k_cache if k_cache.dtype == qg.dtype else k_cache.astype(qg.dtype)
    vc = v_cache if v_cache.dtype == qg.dtype else v_cache.astype(qg.dtype)
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qg, kc, preferred_element_type=jnp.float32,
    ) * (d ** -0.5)                                      # (B, Hkv, G, S) f32
    kpos = jnp.arange(sl)[None, None, None, :]
    s = jnp.where(kpos < local_len[:, None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)                              # (B, Hkv, G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)

    if axis:
        g_m = jax.lax.pmax(m, axis)
        scale = jnp.exp(m - g_m)
        l = jax.lax.psum(l * scale, axis)
        o = jax.lax.psum(o * scale[..., None], axis)
    l = jnp.where(l == 0.0, 1.0, l)
    out = (o / l[..., None]).astype(q.dtype)
    return out.reshape(b, h, d)


def _batch_spec(mesh, batch: int):
    """Batch-dim shard_map spec: ('pod','data') when divisible, else the
    largest prefix that divides, else replicated (the long_500k batch=1
    case — the data axis idles, recorded honestly in the roofline)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    kept = []
    denom = 1
    for a in ba:
        if batch % (denom * sizes[a]) == 0:
            kept.append(a)
            denom *= sizes[a]
    if not kept:
        return None
    return tuple(kept) if len(kept) > 1 else kept[0]


def decode_attention_seqsharded(
    cfg, q, k_cache, v_cache, new_k, new_v, lengths, ctx: ShardCtx
):
    """q (B,H,D), caches (B,S,Hkv,D) with S sharded over 'model'."""
    model_size = ctx.axis_size("model")
    if ctx.mesh is None or model_size <= 1:
        out, kc, vc = _local_decode(
            q, k_cache, v_cache, new_k, new_v, lengths,
            seq_per_shard=k_cache.shape[1], axis=None,
        )
        return out, kc, vc

    mesh = ctx.mesh
    s = k_cache.shape[1]
    assert s % model_size == 0, (s, model_size)
    bspec = _batch_spec(mesh, q.shape[0])
    qs = P(bspec, None, None)
    cs = P(bspec, "model", None, None)
    ks = P(bspec, None, None)
    ls = P(bspec)
    fn = partial(_local_decode, seq_per_shard=s // model_size, axis="model")
    out, kc, vc = shard_map(
        fn,
        mesh=mesh,
        in_specs=(qs, cs, cs, ks, ks, ls),
        out_specs=(qs, cs, cs),
        check_vma=False,
    )(q, k_cache, v_cache, new_k, new_v, lengths)
    return out, kc, vc


def decode_attention_block(cfg, x, lp, cache_k, cache_v, lengths, ctx: ShardCtx,
                           *, window: int = 0):
    """One decode step through an attention block. x: (B, 1, D).

    Returns (out (B,1,D), new_cache_k, new_cache_v). ``window>0`` means the
    cache is a ring buffer of that size (positions stored mod window).
    """
    q, k, v = qkv_proj(cfg, x, lp["wq"], lp["wk"], lp["wv"], ctx)
    pos = lengths[:, None]  # (B, 1) absolute position of the new token
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]

    if window == 0:
        out, kc, vc = decode_attention_seqsharded(
            cfg, q1, cache_k, cache_v, k1, v1, lengths, ctx
        )
    else:
        out, kc, vc = _ring_decode(
            cfg, q1, cache_k, cache_v, k1, v1, lengths, window, ctx
        )
    return out_proj(out[:, None], lp["wo"], ctx), kc, vc


def _ring_decode(cfg, q, cache_k, cache_v, new_k, new_v, lengths, window, ctx):
    """SWA/local decode: ring-buffer cache of size ``window``.

    All slots are valid once length >= window; before that only the first
    ``length+1`` slots are. Softmax is permutation-invariant so slot order
    doesn't matter (RoPE already applied at absolute positions).
    """
    slot = lengths % window
    valid = jnp.minimum(lengths + 1, window)

    model_size = ctx.axis_size("model")
    if ctx.mesh is None or model_size <= 1 or window % model_size != 0:
        def upd(c, n, i):
            return jax.lax.dynamic_update_slice(c, n[None].astype(c.dtype), (i, 0, 0))

        kc = jax.vmap(upd)(cache_k, new_k, slot)
        vc = jax.vmap(upd)(cache_v, new_v, slot)
        out = ref.decode_attention(q, kc, vc, valid)
        return out, kc, vc

    mesh = ctx.mesh
    bspec = _batch_spec(mesh, q.shape[0])
    qs = P(bspec, None, None)
    cs = P(bspec, "model", None, None)
    ks = P(bspec, None, None)
    ls = P(bspec)

    def body(q, kc, vc, nk, nv, slot, valid):
        sl = kc.shape[1]
        offset = jax.lax.axis_index("model") * sl
        li = slot - offset

        def upd(c, n, i):
            inb = (i >= 0) & (i < sl)
            ic = jnp.clip(i, 0, sl - 1)
            return jnp.where(inb, jax.lax.dynamic_update_slice(c, n[None].astype(c.dtype), (ic, 0, 0)), c)

        kc = jax.vmap(upd)(kc, nk, li)
        vc = jax.vmap(upd)(vc, nv, li)
        local_valid = jnp.clip(valid - offset, 0, sl)
        out = _partial_softmax_attend(q, kc, vc, local_valid, "model")
        return out, kc, vc

    return shard_map(
        body, mesh=mesh,
        in_specs=(qs, cs, cs, ks, ks, ls, ls),
        out_specs=(qs, cs, cs),
        check_vma=False,
    )(q, cache_k, cache_v, new_k, new_v, slot, valid)
