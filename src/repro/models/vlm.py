"""VLM family (llava-next-34b): dense GQA backbone + stub anyres frontend.

Per the assignment, the modality frontend is a STUB: ``input_specs`` supplies
precomputed patch embeddings (B, num_patches, 1024) which a learned
``vision_proj`` maps into the token stream ahead of the text tokens. The
backbone is exactly the dense decoder (transformer.py) — decode/serving is
identical once the prefix is in the KV cache.
"""
from repro.models import transformer as tf

param_shapes = tf.param_shapes
param_logical = tf.param_logical
init_params = tf.init_params
param_count = tf.param_count
active_param_count = tf.active_param_count
forward = tf.forward
loss_fn = tf.loss_fn
make_train_step = tf.make_train_step
prefill = tf.prefill
decode_step = tf.decode_step
input_specs = tf.input_specs
cache_shapes = tf.cache_shapes
roofline_units = tf.roofline_units
