"""Optimizers: AdamW (+ dtype-configurable moments) and Adafactor.

Optimizer state shards identically to the parameters (ZeRO-equivalent under
the FSDPxTP rules); ``moment_dtype="bfloat16"`` halves optimizer HBM for the
480B-class models. Updates are returned (not applied) so train_step controls
the parameter dtype cast.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return fn


def _tree_global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


@dataclass(frozen=True)
class AdamW:
    schedule: Callable = field(default_factory=lambda: constant_schedule(1e-3))
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params):
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def state_shapes(self, param_shapes):
        """ShapeDtypeStruct tree mirroring init() (for the dry-run)."""
        mdt = jnp.dtype(self.moment_dtype)
        z = lambda s: jax.ShapeDtypeStruct(s.shape, mdt)
        return {
            "m": jax.tree.map(z, param_shapes),
            "v": jax.tree.map(z, param_shapes),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def state_logical(self, param_logical):
        return {
            "m": param_logical,
            "v": param_logical,
            "count": "",  # scalar
        }

    def global_norm(self, tree):
        return _tree_global_norm(tree)

    def update(self, grads, state, params):
        count = state["count"] + 1
        gnorm = _tree_global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) if self.clip_norm else 1.0
        lr = self.schedule(count)
        b1, b2 = self.b1, self.b2
        mdt = jnp.dtype(self.moment_dtype)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m32 / (1 - b1 ** count.astype(jnp.float32))
            vhat = v32 / (1 - b2 ** count.astype(jnp.float32))
            u = -lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                       + self.weight_decay * p.astype(jnp.float32))
            return u, m32.astype(mdt), v32.astype(mdt)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        flat_p = jax.tree.leaves(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        return updates, {"m": new_m, "v": new_v, "count": count}


@dataclass(frozen=True)
class Adafactor:
    """Factored second moments for >=2D params: O(sum dims) optimizer HBM."""

    schedule: Callable = field(default_factory=lambda: constant_schedule(1e-3))
    decay: float = 0.99
    eps: float = 1e-30
    clip_norm: float = 1.0

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params):
        def z(p):
            if self._factored(p):
                return {
                    "row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(z, params), "count": jnp.zeros((), jnp.int32)}

    def state_shapes(self, param_shapes):
        def z(p):
            if len(p.shape) >= 2:
                return {
                    "row": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                    "col": jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jax.ShapeDtypeStruct(p.shape, jnp.float32)}

        return {
            "f": jax.tree.map(z, param_shapes),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def state_logical(self, param_logical):
        from repro.distributed.sharding import parse_dims

        def z(logical):
            dims = parse_dims(logical)
            if len(dims) >= 2:
                row = " ".join(d or "." for d in dims[:-1])
                col = " ".join(d or "." for d in (dims[:-2] + dims[-1:]))
                return {"row": row, "col": col}
            return {"v": logical}

        return {"f": jax.tree.map(z, param_logical), "count": ""}

    def global_norm(self, tree):
        return _tree_global_norm(tree)

    def update(self, grads, state, params):
        count = state["count"] + 1
        gnorm = _tree_global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) if self.clip_norm else 1.0
        lr = self.schedule(count)
        d = self.decay

        def upd(g, f):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + self.eps
            if "row" in f:
                row = d * f["row"] + (1 - d) * jnp.mean(g2, axis=-1)
                col = d * f["col"] + (1 - d) * jnp.mean(g2, axis=-2)
                rms = jnp.sqrt(
                    row[..., :, None] * col[..., None, :]
                    / jnp.maximum(jnp.mean(row, axis=-1, keepdims=True)[..., None], self.eps)
                )
                u = -lr * g / jnp.maximum(rms, 1e-12)
                return u, {"row": row, "col": col}
            v = d * f["v"] + (1 - d) * g2
            return -lr * g / jnp.sqrt(jnp.maximum(v, 1e-12)), {"v": v}

        flat_g, tdef = jax.tree.flatten(grads)
        flat_f = [
            dict(zip(("row", "col"), x)) if isinstance(x, tuple) else x
            for x in jax.tree.leaves(
                state["f"], is_leaf=lambda n: isinstance(n, dict) and ("row" in n or "v" in n)
            )
        ]
        out = [upd(g, f) for g, f in zip(flat_g, flat_f)]
        updates = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_f = jax.tree.unflatten(tdef, [o[1] for o in out])
        return updates, {"f": new_f, "count": count}
