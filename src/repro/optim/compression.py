"""Gradient compression for data-parallel collectives.

Int8 quantization with error feedback (EF-SGD style): the quantization
residual is carried in optimizer-adjacent state and re-added next step, so
the compressed all-reduce is unbiased in the long run. Two integration
points:

  * ``Int8ErrorFeedback(inner)`` — optimizer wrapper: quantize grads before
    the inner update (models the compressed DP collective numerically; used
    by tests to show convergence is preserved).
  * ``compressed_psum(x, axis)`` — shard_map building block that actually
    performs the low-precision collective: int8-quantize per-tensor-scale,
    psum the int32 accumulator, dequantize. 4x fewer bytes on the wire than
    fp32 psum (v5e ICI is the collective roofline term this attacks).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

# compressed_psum runs inside shard_map over a mesh; pull in the launch
# subsystem's jax forward-compat polyfills (make_mesh axis_types, AxisType,
# shard_map check_vma) so mesh construction works on the pinned JAX.
import repro.kernels.launch  # noqa: F401


def _quantize(x: jax.Array):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_dequantize(x: jax.Array):
    q, scale = _quantize(x.astype(jnp.float32))
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str):
    """int8 quantize -> int32 psum -> dequantize (inside shard_map)."""
    xf = x.astype(jnp.float32)
    q, scale = _quantize(xf)
    # scales differ per shard: psum the max-scale to dequantize conservatively
    gmax = jax.lax.pmax(scale, axis)
    q = jnp.round(xf / gmax).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (total.astype(jnp.float32) * gmax, n)


@dataclass(frozen=True)
class Int8ErrorFeedback:
    inner: Any

    def init(self, params):
        return {
            "err": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "inner": self.inner.init(params),
        }

    def state_shapes(self, param_shapes):
        return {
            "err": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_shapes
            ),
            "inner": self.inner.state_shapes(param_shapes),
        }

    def state_logical(self, param_logical):
        return {"err": param_logical, "inner": self.inner.state_logical(param_logical)}

    def global_norm(self, tree):
        return self.inner.global_norm(tree)

    def update(self, grads, state, params):
        def comp(g, e):
            corrected = g.astype(jnp.float32) + e
            ghat = quantize_dequantize(corrected)
            return ghat, corrected - ghat

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(state["err"])
        out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
        ghat = jax.tree.unflatten(tdef, [o[0] for o in out])
        err = jax.tree.unflatten(tdef, [o[1] for o in out])
        updates, inner_state = self.inner.update(ghat, state["inner"], params)
        return updates, {"err": err, "inner": inner_state}
