from repro.optim.adamw import AdamW, Adafactor, cosine_schedule, constant_schedule  # noqa: F401
from repro.optim.compression import Int8ErrorFeedback, compressed_psum  # noqa: F401
