"""Fault-tolerant checkpointing: async, atomic, elastic-reshardable.

Layout: ``<dir>/step_<N>/`` containing ``manifest.json`` (tree structure,
shapes, dtypes) + ``arrays.npz``. Writes go to ``step_<N>.tmp`` and are
renamed only when complete — a crash mid-save can never corrupt the latest
checkpoint (restart discovery simply ignores ``*.tmp``). Saves run on a
background thread (training continues); ``wait()`` joins before the next
save or shutdown.

Elastic restore: arrays are loaded host-side and ``jax.device_put`` with the
*target* sharding — a checkpoint written on one mesh restores onto any other
mesh (different device count / topology), which is the restart path after a
failed pod is replaced or the job is rescaled.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

# Elastic restore re-meshes on load; the launch subsystem's forward-compat
# polyfills (make_mesh axis_types, AxisType) make that version-portable.
import repro.kernels.launch  # noqa: F401


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_", 1)[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[Future] = None
        self._lock = threading.Lock()

    # ------------------------------ save ------------------------------ #
    def save(self, step: int, tree: Any) -> None:
        # snapshot to host memory synchronously (cheap), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "num_leaves": len(host),
            "dtypes": [str(a.dtype) for a in host],
            "shapes": [list(a.shape) for a in host],
        }
        if self.async_save:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, manifest)
        else:
            self._write(step, host, manifest)

    def _write(self, step: int, host, manifest) -> None:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), *host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        with self._lock:
            steps = sorted(
                int(n.split("_", 1)[1])
                for n in os.listdir(self.directory)
                if n.startswith("step_") and not n.endswith(".tmp")
            )
            for s in steps[: -self.keep] if self.keep else []:
                shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def close(self) -> None:
        """Drain pending saves and join the writer thread (non-daemon —
        leaving it alive trips the test session's leaked-thread guard)."""
        self.wait()
        self._pool.shutdown(wait=True)

    # ----------------------------- restore ---------------------------- #
    def restore(self, step: int, target: Any = None) -> Any:
        """Restore step. ``target``: pytree of arrays or ShapeDtypeStructs
        (possibly with .sharding) — enables elastic re-mesh on load."""
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        host = [data[f"arr_{i}"] for i in range(manifest["num_leaves"])]
        treedef = _deserialize_treedef(manifest["treedef"])
        tree = jax.tree_util.tree_unflatten(treedef, host)
        if target is not None:
            def place(t, a):
                sh = getattr(t, "sharding", None)
                a = np.asarray(a).astype(t.dtype) if hasattr(t, "dtype") else np.asarray(a)
                if sh is not None:
                    return jax.device_put(a, sh)
                return jax.device_put(a)

            tree = jax.tree.map(place, target, tree)
        return tree

    def restore_latest(self, target: Any = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, self.restore(step, target)


def _deserialize_treedef(proto_hex: str):
    from jax.tree_util import PyTreeDef, default_registry

    return PyTreeDef.deserialize_using_proto(default_registry, bytes.fromhex(proto_hex))
