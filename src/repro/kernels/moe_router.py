"""Fused MoE top-k gating (Pallas TPU).

softmax over experts + iterative top-k (k is small and static: 2 for both
assigned MoE archs) + renormalization, in one VMEM-resident pass over the
token block. Grid (num_token_blocks,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch

NEG_INF = -1e30


def _router_kernel(logits_ref, w_ref, idx_ref, *, k: int):
    logits = logits_ref[...].astype(jnp.float32)          # (Bt, E)
    probs = jax.nn.softmax(logits, axis=-1)

    remaining = probs
    ws, idxs = [], []
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)              # (Bt,)
        w = jnp.max(remaining, axis=-1)
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, remaining.shape, 1)
            == idx[:, None]
        )
        remaining = jnp.where(onehot, NEG_INF, remaining)
        ws.append(w)
        idxs.append(idx)

    w = jnp.stack(ws, axis=-1)                            # (Bt, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    w_ref[...] = w.astype(w_ref.dtype)
    idx_ref[...] = jnp.stack(idxs, axis=-1).astype(jnp.int32)


def moe_router_tk(
    logits: jax.Array,  # (T, E)
    k: int,
    *,
    block_t: int = 1024,
    interpret: bool | None = None,
):
    t, e = logits.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    nt = t // block_t

    kernel = functools.partial(_router_kernel, k=k)
    w, idx = launch.pallas_call(
        kernel,
        name="moe_router",
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, e), lambda ti: (ti, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda ti: (ti, 0)),
            pl.BlockSpec((block_t, k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), logits.dtype),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
        ],
        dimension_semantics=("parallel",),
        interpret=interpret,
        rows=t,
    )(logits)
    return w, idx
