"""Blocked causal flash attention (Pallas TPU), with GQA + sliding window.

Layout: q/k/v flattened to (B*H, S, D) / (B*Hkv, S, D); grid
(BH, num_q_blocks, num_kv_blocks) with the kv dimension innermost
("arbitrary" semantics) carrying the online-softmax state (m, l, acc) in VMEM
scratch. Causal / sliding-window blocks that are fully masked are skipped
with ``pl.when`` so the kernel does ~half (causal) or O(window) work.

VMEM working set per program: q block (Bq, D) + k/v blocks (Bk, D) each +
acc (Bq, D) f32 + stats — with Bq=Bk=128, D<=256 this is < 0.5 MB, far under
the ~16 MB v5e VMEM budget; MXU contractions are (128, D)x(D, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int, block_q: int, block_k: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (Bq, D)
        k = k_ref[0].astype(jnp.float32)          # (Bk, D)
        v = v_ref[0].astype(jnp.float32)          # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # (Bq, Bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (Bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                     # (Bq, Bk)
        correction = jnp.exp(m_prev - m_new)       # (Bq, 1)
        l_ref[...] = l_ref[...] * correction + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * correction + pv
        m_ref[...] = m_new

    if causal or window > 0:
        # Block-level visibility: skip fully-masked blocks entirely, so the
        # kernel does ~half (causal) or O(window/seq) (SWA) of the work.
        visible = jnp.asarray(True)
        if causal:
            visible = visible & (k_start <= q_start + block_q - 1)
        if window > 0:
            visible = visible & (k_start + block_k - 1 >= q_start - window + 1)
        pl.when(visible)(_compute)
    else:
        _compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)            # fully-masked rows -> 0 output
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,   # (BH, Sq, D)
    k: jax.Array,   # (BHkv, Sk, D)
    v: jax.Array,   # (BHkv, Sk, D)
    *,
    group: int,     # H // Hkv
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = (d ** -0.5) if scale is None else scale

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
    )
    grid = (bh, nq, nk)
    return launch.pallas_call(
        kernel,
        name="flash_attention",
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki, g=group: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki, g=group: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            launch.VMEM((block_q, d), jnp.float32),
            launch.VMEM((block_q, 1), jnp.float32),
            launch.VMEM((block_q, 1), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        rows=bh * sq,
    )(q, k, v)
