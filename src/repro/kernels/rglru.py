"""RG-LRU linear recurrence (Pallas TPU) — recurrentgemma / Griffin.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t),
a_t = exp(-c * softplus(a_param) * sigmoid(r_t)).

Grid (B, num_width_blocks, num_seq_chunks): the time dimension is innermost
("arbitrary") carrying the hidden state in VMEM scratch across chunks, so
sequence length is unbounded by VMEM. Within a chunk the linear recurrence is
an ``associative_scan`` (log-depth, fully vectorized on the VPU — the
TPU-idiomatic formulation; no per-timestep scalar loop): composing
(a, b) |-> h -> a*h + b gives h_t = Acum_t * h_chunk_start + Bcum_t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch


def _rglru_kernel(
    x_ref, r_ref, i_ref, a_ref, h0_ref, o_ref, hlast_ref, h_ref,
    *, c: float, block_s: int, num_seq_chunks: int,
):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)   # (Bs, Bw)
    r = r_ref[0].astype(jnp.float32)
    i = i_ref[0].astype(jnp.float32)
    a_param = a_ref[...].astype(jnp.float32)  # (1, Bw)

    log_a = -c * jax.nn.softplus(a_param) * jax.nn.sigmoid(r)  # (Bs, Bw)
    a = jnp.exp(log_a)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    inp = multiplier * jax.nn.sigmoid(i) * x

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    acum, bcum = jax.lax.associative_scan(combine, (a, inp), axis=0)
    out = acum * h_ref[...] + bcum          # h_ref broadcasts (1, Bw)
    o_ref[0] = out.astype(o_ref.dtype)
    h_ref[...] = out[-1:]

    @pl.when(si == num_seq_chunks - 1)
    def _final():
        hlast_ref[0] = out[-1].astype(hlast_ref.dtype)


def rglru_bsw(
    x: jax.Array,        # (B, S, W)
    r: jax.Array,        # (B, S, W)
    i: jax.Array,        # (B, S, W)
    a_param: jax.Array,  # (W,)
    h0: jax.Array,       # (B, W)
    *,
    c: float = 8.0,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool | None = None,
):
    b, s, w = x.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0, (s, w, block_s, block_w)
    ns, nw = s // block_s, w // block_w
    a2d = a_param.reshape(1, w)

    kernel = functools.partial(
        _rglru_kernel, c=c, block_s=block_s, num_seq_chunks=ns
    )
    out, hlast = launch.pallas_call(
        kernel,
        name="rglru",
        grid=(b, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, si: (0, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, si: (bi, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_w), lambda bi, wi, si: (bi, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, w), x.dtype),
            jax.ShapeDtypeStruct((b, w), x.dtype),
        ],
        scratch_shapes=[launch.VMEM((1, block_w), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        rows=b * s,
    )(x, r, i, a2d, h0)
    return out, hlast
