"""Public kernel entry points.

``impl`` selects the compute path:
  * "pallas"  — the Pallas kernel; compiled on TPU, interpret=True elsewhere
                (the CPU interpreter executes the kernel body in Python —
                this is how kernels are validated in this container).
  * "xla"     — the pure-jnp oracle from ref.py. This is the dry-run path so
                XLA ``cost_analysis()`` sees the FLOPs (pallas_call is opaque
                to it); on real TPU "pallas" is the production path.
  * "auto"    — "pallas" on TPU, "xla" otherwise.

All wrappers take the model-natural layouts and handle the kernel-layout
transposes / flattening internally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_bkgd
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.hsv_color import hsv_color_hist
from repro.kernels.launch import resolve_impl as _resolve
from repro.kernels.moe_router import moe_router_tk
from repro.kernels.rglru import rglru_bsw
from repro.kernels.ssd import ssd_bhcp


# --------------------------------------------------------------------------- #
def flash_attention(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, S, Hkv, D)
    v: jax.Array,   # (B, S, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
    chunk_q: int = ref.Q_CHUNK,
    unroll: bool = False,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        return ref.mha_attention(q, k, v, causal=causal, window=window,
                                 chunk_q=chunk_q, unroll=unroll)

    b, s, h, d = q.shape
    hkv = k.shape[2]
    group = h // hkv
    # pad seq to a block multiple; causal masking makes tail padding inert for
    # the valid rows (padded q rows are sliced off).
    blk = max(block_q, block_k)
    pad = (-s) % blk
    if pad:
        assert causal, "non-causal flash path requires block-aligned seq"
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sp = s + pad
    qf = qp.transpose(0, 2, 1, 3).reshape(b * h, sp, d)
    kf = kp.transpose(0, 2, 1, 3).reshape(b * hkv, sp, d)
    vf = vp.transpose(0, 2, 1, 3).reshape(b * hkv, sp, d)
    of = flash_attention_bhsd(
        qf, kf, vf,
        group=group, causal=causal, window=window,
        block_q=min(block_q, sp), block_k=min(block_k, sp),
    )
    out = of.reshape(b, h, sp, d).transpose(0, 2, 1, 3)
    return out[:, :s]


def decode_attention(
    q: jax.Array,        # (B, H, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,)
    *,
    impl: str = "auto",
    block_k: int = 256,
) -> jax.Array:
    impl = _resolve(impl)
    if impl == "xla":
        return ref.decode_attention(q, k_cache, v_cache, lengths)

    b, h, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qf = q.reshape(b, hkv, g, d).reshape(b * hkv, g, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    of = decode_attention_bkgd(
        qf, kf, vf, lengths,
        num_kv_heads=hkv, block_k=min(block_k, s),
    )
    return of.reshape(b, hkv, g, d).reshape(b, h, d)


def rglru(
    x: jax.Array,        # (B, S, W)
    r: jax.Array,
    i: jax.Array,
    a_param: jax.Array,  # (W,)
    h0: jax.Array | None = None,
    *,
    c: float = 8.0,
    impl: str = "auto",
    block_s: int = 256,
    block_w: int = 512,
):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.rglru(x, r, i, a_param, h0, c=c)
    b, s, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), x.dtype)
    return rglru_bsw(
        x, r, i, a_param, h0,
        c=c, block_s=min(block_s, s), block_w=min(block_w, w),
    )


def ssd(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    h0: jax.Array | None = None,
    *,
    chunk: int = 64,
    impl: str = "auto",
):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.ssd(x, dt, A, Bm, Cm, h0, chunk=chunk)
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y, hl = ssd_bhcp(
        x.transpose(0, 2, 1, 3),
        dt.transpose(0, 2, 1),
        A,
        Bm.transpose(0, 2, 1, 3),
        Cm.transpose(0, 2, 1, 3),
        h0,
        chunk=min(chunk, s),
    )
    return y.transpose(0, 2, 1, 3), hl


def ssd_decode_step(x, dt, A, Bm, Cm, h, *, impl: str = "auto"):
    # O(1) recurrent step: pure-jnp path is already optimal (tiny tensors).
    return ref.ssd_decode_step(x, dt, A, Bm, Cm, h)


def hsv_color_classify(
    crops: jax.Array,              # (B, H, W, 3) RGB [0,255]
    ranges: jax.Array | None = None,
    *,
    impl: str = "auto",
    block_rows: int = 64,
):
    impl = _resolve(impl)
    if ranges is None:
        ranges = jnp.asarray(ref.COLOR_RANGES)
    if impl == "xla":
        return ref.hsv_color_classify(crops, ranges)
    hist = hsv_color_hist(
        crops, ranges,
        block_rows=min(block_rows, crops.shape[1]),
    )
    return hist, jnp.argmax(hist, axis=-1)


def moe_topk_router(logits: jax.Array, k: int, *, impl: str = "auto", block_t: int = 1024):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.moe_topk_router(logits, k)
    t = logits.shape[0]
    return moe_router_tk(
        logits, k, block_t=min(block_t, t)
    )
