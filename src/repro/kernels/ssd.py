"""Mamba-2 SSD chunked scan (Pallas TPU).

Grid (B, H, num_chunks), chunks innermost ("arbitrary") carrying the
(P, N) SSM state in VMEM scratch. Per chunk the kernel does the
state-space-duality decomposition:

  intra-chunk: Y  = ((C B^T) .* L) (dt .* X)   — quadratic in chunk length,
                                                  all MXU matmuls
  inter-chunk: Y += (C h_in) with start-decay;  h_out = total_decay * h_in
                                                  + end-decayed B^T (dt X)

Chunk length 64–128 and N=128, P=64 give MXU-aligned contractions; the VMEM
working set is O(L*(P+2N) + P*N) floats per program (~0.2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch


def _ssd_kernel(
    x_ref,    # (1, 1, L, P)
    dt_ref,   # (1, 1, L)
    a_ref,    # (1,) SMEM
    b_ref,    # (1, 1, L, N)
    c_ref,    # (1, 1, L, N)
    h0_ref,   # (1, 1, P, N)
    y_ref,    # (1, 1, L, P)
    hl_ref,   # (1, 1, P, N)
    h_ref,    # scratch (P, N) f32
    *, num_chunks: int, chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)    # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (L,)
    A = a_ref[0]                            # scalar
    B = b_ref[0, 0].astype(jnp.float32)    # (L, N)
    C = c_ref[0, 0].astype(jnp.float32)    # (L, N)

    dA = dt * A                             # (L,) log-decay per step
    dA_cum = jnp.cumsum(dA)                 # (L,)

    # intra-chunk decay matrix L[l, m] = exp(sum_{m<r<=l} dA_r), lower-tri
    seg = dA_cum[:, None] - dA_cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(            # C B^T: (L, L)
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    att = scores * Lmat * dt[None, :]
    xdt = x                                   # dt applied via att column scale
    y = jax.lax.dot_general(                  # (L, P)
        att, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # inter-chunk: contribution of the state entering this chunk
    in_decay = jnp.exp(dA_cum)                # (L,)
    ch = jax.lax.dot_general(                 # C h_in: (L, P)
        C, h_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y = y + in_decay[:, None] * ch
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h_out = total_decay * h_in + sum_l end_decay_l dt_l x_l B_l^T
    end_decay = jnp.exp(dA_cum[-1] - dA_cum)  # (L,)
    xw = x * (dt * end_decay)[:, None]        # (L, P)
    hb = jax.lax.dot_general(                 # (P, N)
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h_ref[...] = jnp.exp(dA_cum[-1]) * h_ref[...] + hb

    @pl.when(ci == num_chunks - 1)
    def _final():
        hl_ref[0, 0] = h_ref[...].astype(hl_ref.dtype)


def ssd_bhcp(
    x: jax.Array,    # (B, H, S, P)
    dt: jax.Array,   # (B, H, S)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, G, S, N)
    Cm: jax.Array,   # (B, G, S, N)
    h0: jax.Array,   # (B, H, P, N)
    *,
    chunk: int = 64,
    interpret: bool | None = None,
):
    b, h, s, p = x.shape
    g, n = Bm.shape[1], Bm.shape[3]
    rep = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, num_chunks=nc, chunk=chunk)
    y, hlast = launch.pallas_call(
        kernel,
        name="ssd",
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,), memory_space=launch.SMEM),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[launch.VMEM((p, n), jnp.float32)],
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
        rows=b * s,
    )(x, dt, A.astype(jnp.float32), Bm, Cm, h0)
    return y, hlast
