"""Version-portable kernel-launch subsystem (Hydro §3.3).

The paper's core observation is that UDF execution details must not leak
into the planner; GRACEFUL makes the same argument for UDF execution
internals sitting behind a uniform costed interface. Before this module,
every Pallas kernel hard-coded its own backend-specific launch path (six
copies of the pallas/interpret/XLA dispatch and of the
``pltpu.CompilerParams`` spelling), which is exactly what broke under the
pinned JAX. This module owns all of it:

(a) **Compat shim** — resolves the JAX API surface that moved between the
    pinned 0.4.37 and newer releases:

      * ``pltpu.TPUCompilerParams`` (<= 0.4.x) vs ``pltpu.CompilerParams``
      * ``jax.make_mesh(..., axis_types=...)`` / ``jax.sharding.AxisType``
      * ``jax.shard_map(..., check_vma=...)`` vs
        ``jax.experimental.shard_map.shard_map(..., check_rep=...)``

    ``install_forward_compat()`` (run at import) also *polyfills* the newer
    public names onto the ``jax`` namespace, so code written against newer
    JAX — including the tier-1 test scripts — runs unchanged on the pinned
    version. On newer JAX every polyfill is a no-op.

(b) **Unified launch wrapper** — ``pallas_call`` is the single launch path
    for every kernel: compiled Pallas on TPU, interpreter elsewhere, with
    ONE ``interpret`` knob (None = auto) instead of six copies.
    ``resolve_impl`` centralizes the pallas/XLA-reference backend choice
    for the public ops wrappers (the XLA oracle is the dry-run path whose
    FLOPs XLA ``cost_analysis()`` can see).

(c) **Per-launch timing hooks** — registered hooks receive a
    ``LaunchEvent`` (kernel name, backend, rows, seconds) after each
    launch; ``connect_stats_board`` feeds them into
    ``StatsBoard.record_eval`` so kernel UDFs report cost-per-row like
    every other predicate (§3.3: statistics are collected DURING
    execution, never a-priori). With no hooks registered the wrapper adds
    no synchronization and no overhead.

    Hooks come in two scopes. GLOBAL hooks (``add_launch_hook(fn)``)
    observe every launch in the process — the right tool for tests and
    ad-hoc profiling. TOKEN hooks (``add_launch_hook(fn, token=...)``)
    are *thread-affine*: they fire only for launches made on threads that
    tagged themselves with the same token via ``set_launch_context`` /
    ``launch_context``. AQPExecutor registers its stats hook under its own
    token and tags every thread it owns, so two executors running
    CONCURRENTLY in one process each record only their own kernel
    launches (per-executor attribution — the old process-global bus
    cross-recorded).
"""
from __future__ import annotations

import enum
import functools
import inspect
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "AxisType", "CompilerParams", "LaunchEvent", "SMEM", "VMEM",
    "add_launch_hook", "clear_launch_context", "compiler_params",
    "connect_stats_board", "current_launch_context", "default_interpret",
    "install_forward_compat", "launch_context", "launch_hooks",
    "make_mesh", "pallas_call", "remove_launch_hook",
    "resolve_compiler_params_cls", "resolve_impl", "set_launch_context",
    "shard_map", "stats_board_hook",
]


# --------------------------------------------------------------------------- #
# (a) compat shim                                                             #
# --------------------------------------------------------------------------- #
def resolve_compiler_params_cls(mod: Any = pltpu) -> type:
    """Resolve the TPU compiler-params class across JAX versions.

    Newer JAX spells it ``CompilerParams``; the pinned 0.4.x line spells it
    ``TPUCompilerParams``. ``mod`` is injectable for tests."""
    cls = getattr(mod, "CompilerParams", None)
    if cls is None:
        cls = getattr(mod, "TPUCompilerParams", None)
    if cls is None:
        raise AttributeError(
            "pallas tpu module exposes neither CompilerParams nor "
            "TPUCompilerParams"
        )
    return cls


CompilerParams = resolve_compiler_params_cls()

# Memory spaces, re-exported so kernel files never touch pltpu directly.
VMEM = pltpu.VMEM
SMEM = pltpu.SMEM


def compiler_params(dimension_semantics: Optional[Sequence[str]] = None, **kw):
    """Build compiler params under whichever spelling this JAX provides."""
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    return CompilerParams(**kw)


class _AxisTypePolyfill(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` (added after 0.4.37).

    The pinned ``jax.make_mesh`` has no axis-type concept — every axis
    behaves as Auto — so the members only need to exist and be distinct."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypePolyfill)


def _accepts_kwarg(fn: Callable, name: str) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return True  # unsignaturable builtin: optimistically assume yes
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return True
    return name in params


_ORIG_MAKE_MESH = jax.make_mesh


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None,
              **kw):
    """``jax.make_mesh`` that tolerates ``axis_types`` on any version.

    On JAX without axis types, ``axis_types`` is accepted and ignored
    (every axis is Auto there, which is what all call sites request)."""
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _accepts_kwarg(_ORIG_MAKE_MESH, "axis_types"):
        kw["axis_types"] = axis_types
    return _ORIG_MAKE_MESH(axis_shapes, axis_names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              check_rep=None, **kw):
    """``jax.shard_map`` across versions.

    Newer JAX exposes ``jax.shard_map(..., check_vma=...)``; 0.4.x has
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``. Either
    keyword is accepted here and translated."""
    check = True
    if check_vma is not None:
        check = check_vma
    elif check_rep is not None:
        check = check_rep
    native = getattr(jax, "shard_map", None)
    if native is not None and native is not shard_map:
        return native(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check, **kw)
    from jax.experimental.shard_map import shard_map as _esm

    return _esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check, **kw)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across versions.

    JAX <= 0.4.x returns ``list[dict]`` (one per computation); newer JAX
    returns the dict directly. Always returns a dict here."""
    out = compiled.cost_analysis()
    if isinstance(out, (list, tuple)):
        out = out[0] if out else {}
    return dict(out or {})


def install_forward_compat() -> None:
    """Polyfill the newer JAX public names onto the pinned version.

    No-op on JAX that already has them. This is what lets downstream code
    (and the test suite) written against newer JAX run on 0.4.37."""
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisTypePolyfill
    if not _accepts_kwarg(_ORIG_MAKE_MESH, "axis_types"):
        jax.make_mesh = make_mesh
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    compiled_cls = jax.stages.Compiled
    if not getattr(compiled_cls.cost_analysis, "_repro_compat", False):
        orig = compiled_cls.cost_analysis

        def _cost_analysis(self):
            out = orig(self)
            if isinstance(out, (list, tuple)):
                out = out[0] if out else {}
            return out

        _cost_analysis._repro_compat = True
        compiled_cls.cost_analysis = _cost_analysis


install_forward_compat()


# --------------------------------------------------------------------------- #
# (b) unified launch path                                                     #
# --------------------------------------------------------------------------- #
def resolve_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU, else 'xla' (the pure-jnp oracle path)."""
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return impl


def default_interpret() -> bool:
    """Interpret everywhere but on a real TPU backend."""
    return jax.default_backend() != "tpu"


def pallas_call(
    kernel: Callable,
    *,
    name: str,
    grid,
    in_specs,
    out_specs,
    out_shape,
    scratch_shapes=None,
    dimension_semantics: Optional[Sequence[str]] = None,
    compiler_kwargs: Optional[dict] = None,
    interpret: Optional[bool] = None,
    rows: Optional[int] = None,
):
    """The single kernel-launch path for every Pallas kernel in the repo.

    ``interpret=None`` auto-selects: compiled Pallas on TPU, the Pallas
    interpreter elsewhere (how kernels are validated on CPU CI). ``rows``
    is the row count reported to timing hooks (defaults to the leading dim
    of the first output)."""
    if interpret is None:
        interpret = default_interpret()
    kw = {}
    if scratch_shapes is not None:
        kw["scratch_shapes"] = scratch_shapes
    launched = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=compiler_params(
            dimension_semantics=dimension_semantics, **(compiler_kwargs or {})
        ),
        interpret=interpret,
        **kw,
    )
    backend = "interpret" if interpret else "pallas"
    if rows is None:
        first = out_shape[0] if isinstance(out_shape, (list, tuple)) else out_shape
        rows = int(first.shape[0]) if first.shape else 1

    @functools.wraps(kernel)
    def call(*args):
        hooks = _snapshot_hooks()
        wd = _WATCHDOG
        if not hooks and wd is None:
            return launched(*args)
        # launch-deadline watchdog (core/faults.LaunchWatchdog): bracket
        # the eager launch so a scan thread can flag it if it hangs — the
        # launching thread is blocked inside XLA and cannot report for
        # itself. Tracer-phase calls are bracketed too (a hang during
        # trace/compile is just as wedging); only the TIMING event below
        # stays eager-only.
        token = wd.begin(name) if wd is not None else None
        t0 = time.perf_counter()
        try:
            out = launched(*args)
        finally:
            if wd is not None:
                wd.end(token)
        if any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(out)):
            # Under jit tracing no launch happened here — the elapsed time
            # is trace/compile time, and the compiled executable bypasses
            # this wrapper on later calls. Hooks observe eager launches
            # only; recording trace time would poison the cost EMA with
            # one sample orders of magnitude above steady state.
            return out
        if not hooks:
            return out
        jax.block_until_ready(out)
        event = LaunchEvent(
            name=name, backend=backend, rows=rows,
            seconds=time.perf_counter() - t0,
        )
        for hook in hooks:
            hook(event)
        return out

    return call


# --------------------------------------------------------------------------- #
# (c) per-launch timing hooks                                                 #
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class LaunchEvent:
    """One kernel launch: what ran, where, over how many rows, how long."""

    name: str
    backend: str  # "pallas" | "interpret"
    rows: int
    seconds: float


_HOOKS: List[Callable[[LaunchEvent], None]] = []
_TOKEN_HOOKS: dict = {}  # launch-context token -> [hooks]
_HOOKS_LOCK = threading.Lock()

# Process-global launch watchdog (core/faults.LaunchWatchdog or None).
# Kernel launches are process-wide resources, so unlike the timing hooks
# this seam is NOT token-scoped: any in-flight launch past its deadline is
# worth flagging regardless of which executor issued it.
_WATCHDOG = None


def set_launch_watchdog(wd):
    """Install the process-global launch watchdog; returns the previous
    one (restore it when done — tests use try/finally)."""
    global _WATCHDOG
    prev = _WATCHDOG
    _WATCHDOG = wd
    return prev


def current_launch_watchdog():
    return _WATCHDOG

# Thread-affine launch context: a worker/eddy thread tags itself with its
# executor's token; token-scoped hooks fire only for launches made on
# matching threads (per-executor attribution).
_TLS = threading.local()


def set_launch_context(token) -> None:
    """Tag the CURRENT thread's launches with ``token`` (None = untagged)."""
    _TLS.token = token


def clear_launch_context() -> None:
    _TLS.token = None


def current_launch_context():
    return getattr(_TLS, "token", None)


@contextmanager
def launch_context(token):
    """Scoped ``set_launch_context`` that restores the previous tag."""
    prev = current_launch_context()
    set_launch_context(token)
    try:
        yield
    finally:
        set_launch_context(prev)


def _snapshot_hooks() -> List[Callable[[LaunchEvent], None]]:
    if not _HOOKS and not _TOKEN_HOOKS:  # fast path: no lock, no overhead
        return []
    token = current_launch_context()
    with _HOOKS_LOCK:
        hooks = list(_HOOKS)
        if token is not None:
            hooks.extend(_TOKEN_HOOKS.get(token, ()))
        return hooks


def add_launch_hook(fn: Callable[[LaunchEvent], None], *, token=None):
    """Register a hook; with ``token``, only launches from threads tagged
    with the same launch context (``set_launch_context``) are observed."""
    with _HOOKS_LOCK:
        if token is None:
            _HOOKS.append(fn)
        else:
            _TOKEN_HOOKS.setdefault(token, []).append(fn)
    return fn


def remove_launch_hook(fn: Callable[[LaunchEvent], None]) -> None:
    with _HOOKS_LOCK:
        if fn in _HOOKS:
            _HOOKS.remove(fn)
        for token, hooks in list(_TOKEN_HOOKS.items()):
            if fn in hooks:
                hooks.remove(fn)
            if not hooks:
                del _TOKEN_HOOKS[token]


@contextmanager
def launch_hooks(*fns: Callable[[LaunchEvent], None]):
    for fn in fns:
        add_launch_hook(fn)
    try:
        yield
    finally:
        for fn in fns:
            remove_launch_hook(fn)


def stats_board_hook(board) -> Callable[[LaunchEvent], None]:
    """Hook feeding launches into ``StatsBoard.record_eval``.

    Kernels are compute UDFs, not filters, so rows_in == rows_out; what the
    board learns is the cost-per-row EMA the routing policies consume.
    Lazily-created kernel entries use the board's configured ``cost_alpha``
    so kernel cost estimates share the estimator horizon of every other
    predicate on the board. Entry creation goes through
    ``board.ensure_kernel``, which is thread-safe (launches report from
    predicate worker threads while the eddy thread reads the same board)
    and namespaces the entry ``kernel:<name>`` if a declared routing
    predicate already owns the kernel's launch name."""

    def hook(event: LaunchEvent) -> None:
        board.ensure_kernel(event.name).record_eval(
            event.rows, event.rows, event.seconds
        )

    return hook


def connect_stats_board(board, *, token=None) -> Callable[[LaunchEvent], None]:
    """Register (and return, for later removal) a stats-board hook.

    With ``token``, the hook is thread-affine: only launches from threads
    tagged with that launch context reach ``board`` — how concurrent
    executors keep per-executor attribution."""
    return add_launch_hook(stats_board_hook(board), token=token)
