"""GQA flash-decode (Pallas TPU): one new token vs a long KV cache.

Layout: q reshaped to (B, Hkv, G, D) — the G query heads of one kv group are
processed together so the (G, D) x (D, Bk) contraction feeds the MXU.
Grid (B*Hkv, num_kv_blocks), kv innermost with online-softmax scratch.
Valid-length masking comes from a per-sequence ``lengths`` array so the same
executable serves any fill level of the cache (no recompilation per step —
this is the TPU analogue of Hydro's batch-agnostic workers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, block_k: int, num_kv_blocks: int,
):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)   # (G, D)
        k = k_ref[0].astype(jnp.float32)   # (Bk, D)
        v = v_ref[0].astype(jnp.float32)   # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                           # (G, Bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    # Skip cache blocks entirely beyond the valid length.
    pl.when(k_start < length)(_compute)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_bkgd(
    q: jax.Array,        # (B*Hkv, G, D)
    k_cache: jax.Array,  # (B*Hkv, S, D)
    v_cache: jax.Array,  # (B*Hkv, S, D)
    lengths: jax.Array,  # (B,) int32
    *,
    num_kv_heads: int,
    scale: float | None = None,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    bh, g, d = q.shape
    s = k_cache.shape[1]
    block_k = min(block_k, s)
    assert s % block_k == 0, (s, block_k)
    nk = s // block_k
    scale = (d ** -0.5) if scale is None else scale
    lengths2d = lengths.reshape(-1, 1).astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, num_kv_blocks=nk
    )
    return launch.pallas_call(
        kernel,
        name="decode_attention",
        grid=(bh, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1), lambda b, ki, h=num_kv_heads: (b // h, 0),
                memory_space=launch.SMEM,
            ),
            pl.BlockSpec((1, g, d), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        scratch_shapes=[
            launch.VMEM((g, d), jnp.float32),
            launch.VMEM((g, 1), jnp.float32),
            launch.VMEM((g, 1), jnp.float32),
        ],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
        rows=bh * g,
    )(lengths2d, q, k_cache, v_cache)
