"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests assert against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose), and
they double as the XLA compute path used by the dry-run so that
``cost_analysis()`` sees the FLOPs (Pallas calls are opaque to it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# attention                                                                    #
# --------------------------------------------------------------------------- #
def _gqa_expand(k: jax.Array, num_heads: int) -> jax.Array:
    """(B, S, Hkv, D) -> (B, S, H, D) by repeating kv heads per group."""
    b, s, hkv, d = k.shape
    group = num_heads // hkv
    return jnp.repeat(k, group, axis=2)


def _attn_dense(q, k, v, *, causal, window, q_offset, k_offset=0, kv_len=None):
    """One dense attention tile; q (B,Sq,H,D) vs k/v (B,Sk,H,D) fp32 math."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits *= scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :] + k_offset
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    mask = mask[None, None]
    if kv_len is not None:
        # kv_len masks ABSOLUTE positions (kpos includes k_offset)
        mask = mask & (kpos[None, None] < kv_len[:, None, None, None])
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


Q_CHUNK = 512  # XLA-path q blocking: bounds the live S x S score tile


def mha_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Hkv, D)
    v: jax.Array,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,            # >0: sliding window (causal)
    q_offset: int = 0,          # absolute position of q[0] (for decode/chunks)
    kv_len: jax.Array | None = None,  # (B,) valid kv length (masks the rest)
    chunk_q: int = Q_CHUNK,     # 0 disables chunking (dense)
    unroll: bool = False,       # Python-unroll chunks (exact FLOPs accounting)
) -> jax.Array:
    """Reference attention: GQA, causal, sliding-window, length masking.

    Memory-bounded XLA formulation: q is processed in chunk_q blocks under a
    checkpointed ``lax.map`` (so only one (Bq, Sk) score tile is live — the
    flash-kernel working-set property, expressed in XLA). Sliding-window
    attention slices k/v to a (window + chunk) band per block, keeping both
    memory AND compiled FLOPs sub-quadratic for SWA archs (h2o-danube).

    ``unroll=True`` emits the chunks as straight-line HLO instead of a map —
    used by the roofline delta-lowerings, because XLA ``cost_analysis()``
    counts a map body ONCE (calibrated; see roofline/analysis.py).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)

    if chunk_q <= 0 or sq <= chunk_q or sq % chunk_q != 0:
        return _attn_dense(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, kv_len=kv_len)

    nchunks = sq // chunk_q
    qc = q.reshape(b, nchunks, chunk_q, h, d)
    banded = window > 0 and window + chunk_q < sk
    band = window + chunk_q

    def one(qb, ci):
        if banded:
            start = jnp.clip(ci * chunk_q - window, 0, sk - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            return _attn_dense(
                qb, kb, vb, causal=causal, window=window,
                q_offset=q_offset + ci * chunk_q, k_offset=start, kv_len=kv_len,
            )
        return _attn_dense(
            qb, k, v, causal=causal, window=window,
            q_offset=q_offset + ci * chunk_q, kv_len=kv_len,
        )

    if unroll:
        outs = [one(qc[:, i], jnp.int32(i)) for i in range(nchunks)]
        out = jnp.stack(outs, axis=0)
    else:
        fn = jax.checkpoint(lambda args: one(*args))
        out = jax.lax.map(fn, (jnp.moveaxis(qc, 1, 0), jnp.arange(nchunks)))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)


def decode_attention(
    q: jax.Array,       # (B, H, D) one new token per sequence
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    lengths: jax.Array,  # (B,) int32 — number of valid cache entries
) -> jax.Array:
    out = mha_attention(
        q[:, None], k_cache, v_cache, causal=False, kv_len=lengths
    )
    return out[:, 0]


# --------------------------------------------------------------------------- #
# RG-LRU (recurrentgemma / griffin)                                            #
# --------------------------------------------------------------------------- #
def rglru(
    x: jax.Array,        # (B, S, W) gated input
    r: jax.Array,        # (B, S, W) recurrence gate pre-activation
    i: jax.Array,        # (B, S, W) input gate pre-activation
    a_param: jax.Array,  # (W,) learnable Lambda pre-activation
    h0: jax.Array | None = None,  # (B, W) initial state
    *,
    c: float = 8.0,
):
    """RG-LRU: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t).

    a_t = exp(-c * softplus(a_param) * sigmoid(r_t)). Returns (h_seq, h_last).
    """
    b, s, w = x.shape
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32)) * jax.nn.sigmoid(
        r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i.astype(jnp.float32)) * xf
    # sqrt(1 - a^2) computed in log space for stability
    multiplier = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    inp = multiplier * gated
    h0 = jnp.zeros((b, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        h = a[:, t] * h + inp[:, t]
        return h, h

    h_last, hs = jax.lax.scan(step, h0, jnp.arange(s))
    return jnp.swapaxes(hs, 0, 1).astype(x.dtype), h_last.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Mamba-2 SSD (state-space duality)                                            #
# --------------------------------------------------------------------------- #
def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd(
    x: jax.Array,    # (B, S, H, P) inputs (already multiplied by dt outside? no: raw)
    dt: jax.Array,   # (B, S, H) positive step sizes
    A: jax.Array,    # (H,) negative-log decay parameter (A < 0 effective)
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    h0: jax.Array | None = None,  # (B, H, P, N)
    *,
    chunk: int = 64,
):
    """Chunked SSD (Mamba-2). G (B/C groups) must divide H. Returns (y, h_last).

    y_t = C_t^T sum_{s<=t} (prod_{s<r<=t} exp(A*dt_r)) dt_s B_s x_s
    computed chunkwise: quadratic intra-chunk + recurrent inter-chunk states.
    """
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert h % g == 0
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    # reshape into chunks
    xc = xf.reshape(b, nc, chunk, h, p)
    dtc = dtf.reshape(b, nc, chunk, h)
    Bc = Bf.reshape(b, nc, chunk, h, n)
    Cc = Cf.reshape(b, nc, chunk, h, n)

    dA = dtc * Af[None, None, None, :]          # (B,NC,L,H) log-decay per step
    dA = jnp.moveaxis(dA, -1, 2)                # (B,NC,H,L)
    dA_cum = jnp.cumsum(dA, axis=-1)            # (B,NC,H,L)

    # ---- intra-chunk (quadratic) ----
    Lmat = jnp.exp(_segsum(dA))                 # (B,NC,H,L,L)
    scores = jnp.einsum("bchln,bcmhn->bchlm", jnp.moveaxis(Cc, 3, 2), Bc)
    # scores[b,c,h,l,m] = C_l . B_m ; weight by Lmat and dt_m
    att = scores * Lmat * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", att, xc)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (B,NC,H,L)
    states = jnp.einsum(
        "bclhn,bchl,bclh,bclhp->bchpn", Bc, decay_to_end, dtc, xc
    )  # (B,NC,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (B,NC,H) total decay of chunk

    def scan_fn(hprev, inputs):
        st, dec = inputs  # (B,H,P,N), (B,H)
        hnew = hprev * dec[..., None, None] + st
        return hnew, hprev  # emit state ENTERING this chunk

    h0f = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    h_last, h_in = jax.lax.scan(
        scan_fn,
        h0f,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,NC,H,P,N) state at chunk start

    # ---- inter-chunk output ----
    in_decay = jnp.exp(dA_cum)  # decay from chunk start to position l
    y_inter = jnp.einsum(
        "bclhn,bchl,bchpn->bclhp", Cc, in_decay, h_in
    )

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), h_last.astype(jnp.float32)


def ssd_decode_step(
    x: jax.Array,    # (B, H, P) one token
    dt: jax.Array,   # (B, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, G, N)
    Cm: jax.Array,   # (B, G, N)
    h: jax.Array,    # (B, H, P, N) state
):
    """Single recurrent SSD step. Returns (y, h_new)."""
    b, hh, p = x.shape
    g = Bm.shape[1]
    rep = hh // g
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)  # (B,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dA = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))  # (B,H)
    hnew = h * dA[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhpn", Bf, dt.astype(jnp.float32), x.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", Cf, hnew)
    return y.astype(x.dtype), hnew


# --------------------------------------------------------------------------- #
# HSV color classification (the paper's DogColorClassifier)                    #
# --------------------------------------------------------------------------- #
# ranges follow the paper's example: red = (0,50,70)..(9,255,255), etc.
COLOR_NAMES = (
    "red", "black", "gray", "yellow", "green", "blue", "purple", "pink",
    "white", "other",
)
# (lo_h, lo_s, lo_v, hi_h, hi_s, hi_v) with H in [0,180), S,V in [0,256)
COLOR_RANGES = np.array(
    [
        [0, 50, 70, 9, 255, 255],      # red
        [0, 0, 0, 180, 255, 45],       # black
        [0, 0, 46, 180, 50, 200],      # gray
        [20, 50, 70, 33, 255, 255],    # yellow
        [34, 50, 70, 85, 255, 255],    # green
        [86, 50, 70, 128, 255, 255],   # blue
        [129, 50, 70, 158, 255, 255],  # purple
        [159, 50, 70, 177, 255, 255],  # pink
        [0, 0, 201, 180, 49, 255],     # white
    ],
    dtype=np.float32,
)


def rgb_to_hsv(rgb: jax.Array) -> jax.Array:
    """RGB in [0,255] -> HSV with H in [0,180), S,V in [0,255] (OpenCV scale)."""
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    diff = mx - mn
    safe = jnp.where(diff == 0, 1.0, diff)
    h = jnp.where(
        mx == r,
        (g - b) / safe % 6.0,
        jnp.where(mx == g, (b - r) / safe + 2.0, (r - g) / safe + 4.0),
    )
    h = jnp.where(diff == 0, 0.0, h) * 30.0  # 60/2 — OpenCV H/2 convention
    s = jnp.where(mx == 0, 0.0, diff / jnp.where(mx == 0, 1.0, mx)) * 255.0
    return jnp.stack([h, s, mx], axis=-1)


def hsv_color_classify(crops: jax.Array, ranges: jax.Array | None = None):
    """(B, H, W, 3) RGB [0,255] -> (B, n_colors+1) pixel-fraction histogram.

    Class = argmax fraction (last bucket = 'other'). Returns (hist, label).
    """
    if ranges is None:
        ranges = jnp.asarray(COLOR_RANGES)
    hsv = rgb_to_hsv(crops.astype(jnp.float32))  # (B,H,W,3)
    px = hsv[:, :, :, None, :]  # (B,H,W,1,3)
    lo = ranges[None, None, None, :, 0:3]
    hi = ranges[None, None, None, :, 3:6]
    inrange = jnp.all((px >= lo) & (px <= hi), axis=-1)  # (B,H,W,C)
    # first matching bucket wins (paper checks ranges in order)
    first = jnp.cumsum(inrange, axis=-1) == 1
    inrange = inrange & first
    other = ~jnp.any(inrange, axis=-1, keepdims=True)
    onehot = jnp.concatenate([inrange, other], axis=-1).astype(jnp.float32)
    hist = onehot.mean(axis=(1, 2))  # (B, C+1)
    return hist, jnp.argmax(hist, axis=-1)


# --------------------------------------------------------------------------- #
# MoE top-k router                                                             #
# --------------------------------------------------------------------------- #
def moe_topk_router(logits: jax.Array, k: int):
    """(T, E) -> (weights (T,k) renormalized softmax, idx (T,k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    weights = vals / jnp.sum(vals, axis=-1, keepdims=True)
    return weights.astype(logits.dtype), idx.astype(jnp.int32)
