"""Pallas TPU kernels for the UDF-side compute hot spots.

Each kernel has a pure-jnp oracle in ref.py (tests assert allclose across
shape/dtype sweeps) and a public wrapper in ops.py (impl dispatch:
pallas-on-TPU / interpret-on-CPU / xla oracle for the dry-run FLOPs path).
"""
from repro.kernels import ops, ref  # noqa: F401
