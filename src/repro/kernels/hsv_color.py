"""HSV color classification (Pallas TPU) — the paper's DogColorClassifier.

The paper classifies object colors by checking pixel values against HSV
ranges (e.g. red = (0,50,70)..(9,255,255)). This kernel fuses RGB->HSV
conversion, range bucketing (first match wins, remainder = 'other') and the
per-image histogram reduction. Grid (B, num_row_blocks): row blocks innermost
accumulate the histogram in VMEM scratch; pixels stream HBM->VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import launch


def _hsv_kernel(
    rgb_ref,    # (1, Br, W, 3)
    rng_ref,    # (C, 6)
    hist_ref,   # (1, C+1) output
    acc_ref,    # scratch (1, C+1) f32
    *, num_row_blocks: int, n_colors: int, total_px: int,
):
    ri = pl.program_id(1)

    @pl.when(ri == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rgb = rgb_ref[0].astype(jnp.float32)    # (Br, W, 3)
    r, g, b = rgb[..., 0], rgb[..., 1], rgb[..., 2]
    mx = jnp.maximum(jnp.maximum(r, g), b)
    mn = jnp.minimum(jnp.minimum(r, g), b)
    diff = mx - mn
    safe = jnp.where(diff == 0, 1.0, diff)
    h = jnp.where(
        mx == r,
        (g - b) / safe % 6.0,
        jnp.where(mx == g, (b - r) / safe + 2.0, (r - g) / safe + 4.0),
    )
    h = jnp.where(diff == 0, 0.0, h) * 30.0
    s = jnp.where(mx == 0, 0.0, diff / jnp.where(mx == 0, 1.0, mx)) * 255.0
    v = mx
    hsv = jnp.stack([h, s, v], axis=-1)     # (Br, W, 3)

    px = hsv[:, :, None, :]                  # (Br, W, 1, 3)
    lo = rng_ref[...][None, None, :, 0:3]
    hi = rng_ref[...][None, None, :, 3:6]
    inrange = jnp.all((px >= lo) & (px <= hi), axis=-1)  # (Br, W, C)
    first = jnp.cumsum(inrange, axis=-1) == 1
    inrange = inrange & first
    other = ~jnp.any(inrange, axis=-1, keepdims=True)
    onehot = jnp.concatenate([inrange, other], axis=-1).astype(jnp.float32)
    acc_ref[...] += jnp.sum(onehot, axis=(0, 1))[None] / total_px

    @pl.when(ri == num_row_blocks - 1)
    def _final():
        hist_ref[...] = acc_ref[...].astype(hist_ref.dtype)


def hsv_color_hist(
    crops: jax.Array,   # (B, H, W, 3) RGB in [0, 255]
    ranges: jax.Array,  # (C, 6) lo/hi HSV
    *,
    block_rows: int = 64,
    interpret: bool | None = None,
) -> jax.Array:
    b, hh, ww, _ = crops.shape
    c = ranges.shape[0]
    block_rows = min(block_rows, hh)
    assert hh % block_rows == 0, (hh, block_rows)
    nr = hh // block_rows

    kernel = functools.partial(
        _hsv_kernel, num_row_blocks=nr, n_colors=c, total_px=hh * ww
    )
    return launch.pallas_call(
        kernel,
        name="hsv_color",
        grid=(b, nr),
        in_specs=[
            pl.BlockSpec((1, block_rows, ww, 3), lambda bi, ri: (bi, ri, 0, 0)),
            pl.BlockSpec((c, 6), lambda bi, ri: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c + 1), lambda bi, ri: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c + 1), jnp.float32),
        scratch_shapes=[launch.VMEM((1, c + 1), jnp.float32)],
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
        rows=b,
    )(crops.astype(jnp.float32), ranges.astype(jnp.float32))
