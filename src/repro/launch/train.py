"""Fault-tolerant training driver.

Production shape: mesh -> sharded params/opt-state -> jitted train_step ->
step loop with async checkpoints, auto-resume, watchdog, heartbeat, and
deterministic failure injection for tests.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 50 --batch 8 --seq 128 --smoke --ckpt-dir /tmp/ckpt

--smoke uses the reduced config (runs on this CPU container); the full
configs are exercised via the dry-run instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import Prefetcher, TokenSource, shard_batch
from repro.distributed.fault_tolerance import (
    FailureInjector, Heartbeat, StepWatchdog,
)
from repro.distributed.sharding import TRAIN_RULES, tree_shape_dtypes
from repro.launch.mesh import make_host_mesh
from repro.models.layers import NULL_CTX, ShardCtx
from repro.models.registry import model_api
from repro.optim import AdamW, cosine_schedule


def build(cfg, mesh=None, *, lr=3e-4, warmup=20, total=1000):
    api = model_api(cfg)
    rules = TRAIN_RULES if mesh is not None else None
    ctx = ShardCtx(mesh, rules) if mesh is not None else NULL_CTX
    opt = AdamW(schedule=cosine_schedule(lr, warmup, total))
    step_fn = api.make_train_step(cfg, opt, ctx)
    return api, opt, ctx, jax.jit(step_fn, donate_argnums=(0, 1))


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    mesh=None,
    injector: Optional[FailureInjector] = None,
    log_every: int = 10,
    seed: int = 0,
) -> dict:
    api, opt, ctx, jstep = build(cfg, mesh)
    source = TokenSource(cfg.vocab_size, seq, seed=seed)

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    params = opt_state = None
    if ckpt is not None and latest_step(ckpt_dir) is not None:
        start_step, state = ckpt.restore_latest()
        params, opt_state, src_state = state
        source.restore(src_state)
        print(f"[train] resumed from step {start_step}")
    if params is None:
        params = api.init_params(cfg, jax.random.key(seed))
        opt_state = opt.init(params)

    watchdog = StepWatchdog()
    hb = Heartbeat(os.path.join(ckpt_dir, "heartbeat")) if ckpt_dir else None
    pf = Prefetcher(lambda: source.next(batch), depth=2)
    losses = []
    try:
        for step in range(start_step + 1, steps + 1):
            if injector is not None:
                injector.check(step)
            t0 = time.perf_counter()
            hbatch = pf.next()
            dbatch = shard_batch(hbatch, mesh, TRAIN_RULES if mesh else None)
            params, opt_state, metrics = jstep(params, opt_state, dbatch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ev = watchdog.observe(dt)
            losses.append(loss)
            if hb is not None:
                hb.beat(step)
            if step % log_every == 0 or step == steps:
                print(f"[train] step={step} loss={loss:.4f} dt={dt*1e3:.1f}ms"
                      + (f" STRAGGLER(>{ev.threshold*1e3:.0f}ms)" if ev else ""))
            if ckpt is not None and (step % ckpt_every == 0 or step == steps):
                # source state = batches CONSUMED (one per step), not the
                # prefetcher's read-ahead position — exact replay on resume
                ckpt.save(step, (params, opt_state, {"step": step}))
    finally:
        pf.stop()
        if ckpt is not None:
            ckpt.close()  # drain + join the writer (leaked-thread guard)
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "losses": losses,
        "stragglers": len(watchdog.events),
        "params": params,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", action="store_true", help="use host-device mesh")
    ap.add_argument("--resume", action="store_true",
                    help="(auto when --ckpt-dir has checkpoints)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduce_for_smoke()
    mesh = make_host_mesh() if args.mesh else None
    out = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, mesh=mesh,
    )
    print(f"[train] done: final_loss={out['final_loss']:.4f} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
