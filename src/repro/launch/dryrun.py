import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines ABOVE this docstring must stay the first two lines of the
module — jax locks the device count on first init, and the production meshes
need 512 placeholder devices. Nothing else in the repo sets this flag.

Per cell this prints/records:
  * compiled.memory_analysis()  — bytes per device (proves it fits)
  * compiled.cost_analysis()    — FLOPs / bytes for the roofline
  * the collective schedule parsed from the per-device HLO

Artifacts land in ``results/dryrun/<cell>.json`` (resumable: existing
artifacts are skipped unless --force). ``--roofline`` additionally lowers
each family's delta-units (L0/L1) to produce exact totals (see
roofline/analysis.py for the calibration notes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --roofline
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config, get_shape  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    SERVE_RULES, TRAIN_RULES, tree_shape_dtypes,
)
from repro.launch.mesh import make_production_mesh, mesh_chip_count  # noqa: E402
from repro.models.layers import ShardCtx  # noqa: E402
from repro.models.registry import model_api  # noqa: E402
from repro.optim import AdamW  # noqa: E402
from repro.roofline import analysis  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

BIG_MODEL_PARAMS = 100e9    # above this, optimizer moments go bf16
HUGE_MODEL_PARAMS = 250e9   # above this, Adafactor (factored second moment)


def choose_optimizer(cfg):
    from repro.optim import Adafactor

    n = model_api(cfg).param_count(cfg)
    if n > HUGE_MODEL_PARAMS:
        return Adafactor()
    return AdamW(moment_dtype="bfloat16" if n > BIG_MODEL_PARAMS else "float32")


def train_overrides(cfg, shape):
    """Per-cell memory-fit knobs (documented in EXPERIMENTS.md §Dry-run).

    grad_accum == 0 is the explicit "forced off" sentinel used by the
    roofline unit lowerings (make_train_step treats it as 1)."""
    if shape.kind == "train" and cfg.d_model >= 2048 and cfg.grad_accum == 1:
        return dataclasses.replace(cfg, grad_accum=8)
    return cfg


def rules_for(shape):
    return TRAIN_RULES if shape.kind == "train" else SERVE_RULES


def lower_cell(cfg, shape, mesh, *, rules=None, opt=None):
    """Lower the step for one cell; returns (lowered, donate-info)."""
    cfg = train_overrides(cfg, shape)
    api = model_api(cfg)
    rules = rules or rules_for(shape)
    ctx = ShardCtx(mesh, rules)
    pshapes, plogical = api.param_shapes(cfg), api.param_logical(cfg)
    params_in = tree_shape_dtypes(pshapes, plogical, rules, mesh)
    inputs = api.input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        opt = opt or choose_optimizer(cfg)
        ostate = tree_shape_dtypes(
            opt.state_shapes(pshapes), opt.state_logical(plogical), rules, mesh
        )
        step = api.make_train_step(cfg, opt, ctx)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn.lower(params_in, ostate, inputs)
    if shape.kind == "prefill":
        fn = jax.jit(lambda p, b: api.prefill(cfg, p, b, ctx))
        return fn.lower(params_in, inputs)
    # decode
    cshapes, clogical = api.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    cache_in = tree_shape_dtypes(cshapes, clogical, rules, mesh)
    fn = jax.jit(lambda p, c, b: api.decode_step(cfg, p, c, b, ctx), donate_argnums=(1,))
    return fn.lower(params_in, cache_in, inputs)


def compile_cell(cfg, shape, mesh, *, default_group: Optional[int] = None):
    t0 = time.time()
    lowered = lower_cell(cfg, shape, mesh)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    dg = default_group or mesh_chip_count(mesh)
    sample = analysis.CostSample.from_compiled(compiled, dg, compile_seconds=t2 - t1)
    return sample, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, roofline: bool,
             outdir: str, force: bool = False) -> dict:
    cfg, shape = get_config(arch), get_shape(shape_name)
    cell_id = f"{arch}__{shape_name}__{mesh_kind}"
    path = os.path.join(outdir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    ok, reason = cell_applicable(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "applicable": ok, "reason": reason, "status": "skipped" if not ok else None,
    }
    if ok:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
        chips = mesh_chip_count(mesh)
        try:
            sample, times = compile_cell(cfg, shape, mesh)
            record.update(
                status="ok",
                chips=chips,
                times=times,
                per_device={
                    "flops_scan_once": sample.flops,
                    "bytes_scan_once": sample.bytes_accessed,
                    "wire_bytes_scan_once": sample.wire_bytes,
                },
                memory=sample.mem,
                collectives=sample.collectives,
            )
            print(f"[{cell_id}] memory_analysis: {sample.mem}")
            print(f"[{cell_id}] cost_analysis: flops/dev={sample.flops:.3e} "
                  f"bytes/dev={sample.bytes_accessed:.3e}")
            colls = {k: v["count"] for k, v in sample.collectives.items() if v["count"]}
            print(f"[{cell_id}] collectives: {colls}")

            if roofline and mesh_kind == "singlepod":
                api = model_api(cfg)
                # TRUE-STEP accounting: a microbatched train step repeats
                # the whole pass (incl. FSDP weight gathers) per microbatch
                # -> lower the pass at the MICRO batch and scale by M.
                eff = train_overrides(cfg, shape)
                m = eff.grad_accum if (shape.kind == "train" and eff.grad_accum > 1) else 1
                pass_shape = (
                    dataclasses.replace(shape, global_batch=shape.global_batch // m)
                    if m > 1 else shape
                )
                base_cfg, units = api.roofline_units(cfg)
                # unit lowerings force grad_accum OFF (sentinel 0, which
                # train_overrides respects): the microbatch scan body is
                # counted once by cost_analysis (like any scan body)
                base_cfg = dataclasses.replace(base_cfg, grad_accum=0)
                units = [(c, dataclasses.replace(u, grad_accum=0)) for c, u in units]
                base_sample, _ = compile_cell(base_cfg, pass_shape, mesh)
                unit_samples = []
                for count, ucfg in units:
                    us, _ = compile_cell(ucfg, pass_shape, mesh)
                    unit_samples.append((count, us))
                totals = analysis.delta_total(base_sample, unit_samples)
                totals = {k: v * m for k, v in totals.items()}
                terms = analysis.roofline_terms(
                    totals["flops"], totals["bytes"], totals["wire"]
                )
                terms["accum_factor"] = m
                mf = analysis.model_flops(cfg, shape)
                hlo_total = totals["flops"] * chips
                record["roofline"] = {
                    "per_device": totals,
                    "terms": terms,
                    "model_flops": mf,
                    "useful_ratio": mf / hlo_total if hlo_total else 0.0,
                }
                print(f"[{cell_id}] roofline terms: {terms}")
        except Exception as e:  # record failures — they are bugs to fix
            record.update(status="error", error=f"{type(e).__name__}: {e}",
                          trace=traceback.format_exc()[-4000:])
            print(f"[{cell_id}] ERROR {type(e).__name__}: {e}")

    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=float)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["singlepod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--outdir", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    meshes = ["singlepod", "multipod"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, roofline=args.roofline,
                               outdir=args.outdir, force=args.force)
                if rec.get("status") == "error":
                    failures += 1
    print(f"dry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
