"""QueryService — the always-on multi-tenant serving layer (ROADMAP item).

Everything below ``launch/`` used to be one-shot: build an ``AQPExecutor``,
run one query, tear it down.  Production ML-query traffic is N concurrent
queries contending for ONE accelerator pool — exactly what the PR-3
thread-affine launch attribution and cross-predicate leasing were built
for.  ``QueryService`` makes the executors long-lived *tenants* of a
shared ``ResourceArbiter``/``DevicePool``:

  service = QueryService(pool=DevicePool({"cpu": 8}), max_concurrent=4)
  h = service.submit(predicates, batches, priority=2.0, deadline_s=5.0)
  report = h.result(timeout=30)      # QueryReport telemetry
  service.close()

API semantics
-------------
``submit(predicates, source, *, priority=1.0, deadline_s=None, qid=None,
**executor_kwargs)`` enqueues a query and returns a ``QueryHandle``
immediately.

* **Admission control** — the pending queue is BOUNDED (``max_pending``):
  a submit that would overflow it raises ``AdmissionError`` synchronously
  (the caller sheds load at the edge instead of queueing unboundedly).
  ``close()`` also rejects new submits.  At most ``max_concurrent``
  queries run at once; the rest wait in priority order.
* **Priority** — higher runs first.  The dispatcher pops the pending heap
  by ``(-priority, earliest deadline, submit order)``, and a running
  query's predicates arbitrate shared-pool slots with an URGENCY weight
  (``policies.urgency_weight(priority, deadline)``) folded into
  ``PressureRanked`` — so a high-priority or deadline-pressed tenant wins
  contended slots at equal measured pressure.  Scheduling is
  PREEMPTION-FREE: admission/completion trigger ``arbiter.rebalance()``
  (stale standing wants cleared), but running queries are never paused
  and held leases never revoked.
* **Deadline** — ``deadline_s`` is relative to submission.  A PENDING
  query still waiting when its deadline passes is EXPIRED without
  running (its handle reports ``state == "EXPIRED"``).  A RUNNING query
  is never killed by its deadline (no preemption); its report records
  ``deadline_met`` so goodput metrics can discount late finishes.
  ``cancel()`` removes a pending query outright and asks a running one
  to stop at the next completed batch (state ``CANCELLED``).
* **Name conflicts** — arbiter registrations are keyed by predicate
  name, so two queries sharing a predicate NAME cannot run concurrently;
  the dispatcher SERIALIZES them (the later one waits, regardless of
  priority) instead of cross-wiring their pipelines.

Cross-query statistics (the live-prior channel): the service owns a
``StatsStore`` (in-memory by default, persistent with ``stats_path=``).
Before dispatching a query it folds every RUNNING executor's live board
into the store (``StatsStore.record_live`` — delta-based, never
double-counts), then warm-starts the newcomer's board from it: query B
starts from query A's in-flight profile, not from roofline priors.

Telemetry: each finished handle carries a structured ``QueryReport`` —
queue-time vs eval-time split, per-predicate cache hit rates, routing
counters, fault/quarantine summary, re-verification counters (executor
knob ``reverify=``), exact output row ids — and every tenant executor's
``stats_snapshot()["_service"]`` identifies its query, priority and
deadline.  Service threads are daemons named ``svc-dispatch`` /
``svc-query-<qid>`` (covered by the tests/conftest leaked-thread guard).

The single-query CLI below is rebuilt ON TOP of the service
(``max_concurrent=1``) — one driver code path for both modes:

  PYTHONPATH=src python -m repro.launch.serve --reviews 200 --policy cost
"""
from __future__ import annotations

import argparse
import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.executor import AQPExecutor
from repro.core.policies import ArbiterPolicy, urgency_weight
from repro.core.resources import DevicePool, ResourceArbiter
from repro.core.statstore import StatsStore
from repro.core.udf import Predicate

MAX_LEN = 512

# Dispatcher poll cadence: how promptly pending-queue deadline expiry is
# noticed when no submit/finish event wakes the dispatcher.
_DISPATCH_POLL_S = 0.05

PENDING = "PENDING"
RUNNING = "RUNNING"
DONE = "DONE"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
EXPIRED = "EXPIRED"


class AdmissionError(RuntimeError):
    """Submit rejected: the bounded pending queue is full (or the service
    is closed).  Raised synchronously from ``submit`` — load is shed at
    the edge, never queued unboundedly."""


@dataclass
class QueryReport:
    """Structured per-query telemetry (returned by ``QueryHandle.result``).

    ``queue_time_s`` is submit -> dispatch; ``eval_time_s`` dispatch ->
    finish; ``deadline_met`` is None for deadline-less queries.
    ``row_ids`` is the exact concatenated output row-id multiset;
    ``board_predicates`` the predicate entries this query's OWN board
    profiled (the cross-query leakage assert: it must only ever contain
    this query's names).  ``routing`` / ``faults`` / ``cache_hit_rates``
    / ``reverify`` summarize the tenant executor's final snapshot."""

    qid: str
    state: str
    priority: float
    deadline_s: Optional[float]
    submitted_at: float
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    queue_time_s: float = 0.0
    eval_time_s: float = 0.0
    deadline_met: Optional[bool] = None
    rows: int = 0
    batches: int = 0
    row_ids: Optional[np.ndarray] = None
    board_predicates: Tuple[str, ...] = ()
    cache_hit_rates: Dict[str, float] = field(default_factory=dict)
    routing: Dict[str, object] = field(default_factory=dict)
    faults: Dict[str, object] = field(default_factory=dict)
    reverify: Optional[Dict[str, int]] = None
    error: str = ""


class QueryHandle:
    """Caller-side handle: await, inspect, or cancel one submitted query."""

    def __init__(self, qid: str, *, priority: float,
                 deadline_abs: Optional[float], report: QueryReport):
        self.qid = qid
        self.priority = priority
        self.deadline_abs = deadline_abs
        self.report = report
        self._pred_names: frozenset = frozenset()
        self.output: List = []          # completed RoutingBatches
        self._done = threading.Event()
        self._cancel = threading.Event()

    @property
    def state(self) -> str:
        return self.report.state

    def done(self) -> bool:
        return self._done.is_set()

    def cancel(self) -> bool:
        """Request cancellation; returns True if the query had not
        already finished.  Pending -> dropped at next dispatch; running
        -> stops at the next completed batch."""
        if self._done.is_set():
            return False
        self._cancel.set()
        return True

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> QueryReport:
        """Block until the query reaches a terminal state; returns the
        ``QueryReport``.  Raises TimeoutError on timeout and RuntimeError
        if the query FAILED (the report stays readable on ``.report``)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"query {self.qid!r} still {self.state}")
        if self.report.state == FAILED:
            raise RuntimeError(
                f"query {self.qid!r} failed: {self.report.error}"
            )
        return self.report


class QueryService:
    """N long-lived executor tenants over one shared arbiter (module
    docstring has the full submit/priority/deadline/admission contract)."""

    def __init__(self, *,
                 pool: Optional[DevicePool] = None,
                 arbiter_policy: Optional[ArbiterPolicy] = None,
                 max_concurrent: int = 2,
                 max_pending: int = 16,
                 stats_store: Optional[StatsStore] = None,
                 stats_path: Optional[str] = None,
                 executor_defaults: Optional[dict] = None):
        if max_concurrent < 1:
            raise ValueError("max_concurrent must be >= 1")
        if max_pending < 0:
            raise ValueError("max_pending must be >= 0")
        self.arbiter = ResourceArbiter(pool=pool, policy=arbiter_policy)
        self.max_concurrent = max_concurrent
        self.max_pending = max_pending
        # the live-prior channel: in-memory unless the caller persists
        self.store = stats_store or StatsStore(stats_path)
        self.executor_defaults = dict(executor_defaults or {})
        self._cv = threading.Condition()
        self._seq = itertools.count()
        self._qid_count = itertools.count()
        # pending heap: (-priority, deadline key, submit seq, handle, ...)
        self._pending: List[tuple] = []
        self._running: Dict[str, QueryHandle] = {}
        # qid -> (executor, predicates, fold bases): the live boards the
        # dispatcher folds into the store before admitting a newcomer
        self._live: Dict[str, tuple] = {}
        self._threads: List[threading.Thread] = []
        self._closed = False
        # service counters (surfaced via snapshot())
        self.submitted = 0
        self.rejected = 0
        self.completed = 0
        self.failed = 0
        self.cancelled = 0
        self.expired = 0
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, daemon=True, name="svc-dispatch"
        )
        self._dispatcher.start()

    # ----------------------------- submit ----------------------------- #
    def submit(self, predicates: List[Predicate], source: Iterable, *,
               priority: float = 1.0, deadline_s: Optional[float] = None,
               qid: Optional[str] = None, **executor_kwargs) -> QueryHandle:
        """Enqueue one query (an iterable of RoutingBatches plus its
        predicates); returns a ``QueryHandle`` immediately.  Raises
        ``AdmissionError`` when the bounded pending queue is full or the
        service is closed."""
        now = time.monotonic()
        qid = qid or f"q{next(self._qid_count)}"
        deadline_abs = None if deadline_s is None else now + deadline_s
        report = QueryReport(
            qid=qid, state=PENDING, priority=float(priority),
            deadline_s=deadline_s, submitted_at=now,
        )
        handle = QueryHandle(qid, priority=float(priority),
                             deadline_abs=deadline_abs, report=report)
        handle._pred_names = frozenset(p.name for p in predicates)
        with self._cv:
            if self._closed:
                raise AdmissionError("service is closed")
            if len(self._pending) >= self.max_pending:
                self.rejected += 1
                raise AdmissionError(
                    f"pending queue full ({self.max_pending}); "
                    f"query {qid!r} rejected"
                )
            self.submitted += 1
            heapq.heappush(self._pending, (
                -float(priority),
                deadline_abs if deadline_abs is not None else float("inf"),
                next(self._seq),
                handle, list(predicates), source, dict(executor_kwargs),
            ))
            self._cv.notify_all()
        return handle

    # --------------------------- dispatcher --------------------------- #
    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while not self._dispatchable_locked():
                    if self._closed and not self._pending:
                        return
                    self._cv.wait(timeout=_DISPATCH_POLL_S)
                    self._expire_locked()
                item = self._pop_eligible_locked()
                if item is None:
                    continue
                handle, predicates, source, kwargs = item
                handle.report.state = RUNNING
                self._running[handle.qid] = handle
            t = threading.Thread(
                target=self._run_query,
                args=(handle, predicates, source, kwargs),
                daemon=True, name=f"svc-query-{handle.qid}",
            )
            with self._cv:
                self._threads.append(t)
            t.start()

    def _dispatchable_locked(self) -> bool:
        return bool(self._pending) and len(self._running) < self.max_concurrent

    def _expire_locked(self) -> None:
        """Drop pending queries whose deadline passed, and honor pending
        cancels, without disturbing heap order for the rest."""
        if not self._pending:
            return
        now = time.monotonic()
        keep = []
        for item in self._pending:
            handle = item[3]
            if handle._cancel.is_set():
                self._finish_pending(handle, CANCELLED)
            elif handle.deadline_abs is not None and now > handle.deadline_abs:
                self._finish_pending(handle, EXPIRED)
            else:
                keep.append(item)
        if len(keep) != len(self._pending):
            self._pending = keep
            heapq.heapify(self._pending)

    def _finish_pending(self, handle: QueryHandle, state: str) -> None:
        handle.report.state = state
        handle.report.finished_at = time.monotonic()
        handle.report.queue_time_s = (
            handle.report.finished_at - handle.report.submitted_at
        )
        if state == EXPIRED:
            self.expired += 1
            handle.report.deadline_met = False
        else:
            self.cancelled += 1
        handle._done.set()

    def _pop_eligible_locked(self) -> Optional[tuple]:
        """Pop the best pending query whose predicate names don't collide
        with a running tenant (name-keyed arbiter registrations — see
        module docstring); colliding entries are pushed back untouched."""
        self._expire_locked()
        running_names = set()
        for h in self._running.values():
            running_names |= h._pred_names
        deferred = []
        picked = None
        while self._pending:
            item = heapq.heappop(self._pending)
            _, _, _, handle, predicates, _, _ = item
            if {p.name for p in predicates} & running_names:
                deferred.append(item)
                continue
            picked = item[3:]
            break
        for item in deferred:
            heapq.heappush(self._pending, item)
        return picked

    # --------------------------- query runner --------------------------- #
    def _fold_live_locked(self) -> None:
        """Fold every running executor's live board into the store (the
        cross-query live-prior channel; delta-based via record_live)."""
        for qid, (ex, preds, bases) in list(self._live.items()):
            try:
                new_bases = self.store.record_live(ex.stats, preds, bases)
            except Exception:
                continue  # a torn-down rival must not block admission
            self._live[qid] = (ex, preds, new_bases)

    def _run_query(self, handle: QueryHandle, predicates: List[Predicate],
                   source: Iterable, kwargs: dict) -> None:
        report = handle.report
        started = time.monotonic()
        report.started_at = started
        report.queue_time_s = started - report.submitted_at
        # deadline/priority-aware arbitration + preemption-free rebalance
        self.arbiter.note_query_admitted(
            handle.qid,
            urgency_weight(handle.priority, handle.deadline_abs, started),
        )
        ex = None
        try:
            merged = dict(self.executor_defaults)
            merged.update(kwargs)
            ex = AQPExecutor(predicates, arbiter=self.arbiter,
                             query=handle.qid, **merged)
            ex.service_info = {
                "managed": True,
                "query": handle.qid,
                "priority": handle.priority,
                "deadline_s": report.deadline_s,
            }
            with self._cv:
                # rivals' live evidence first, then warm-start from it
                self._fold_live_locked()
            seeded = self.store.warm_start(ex.stats, predicates)
            bases = {
                n: c for n, c in ex.stats.batch_counts().items() if c
            }
            del seeded  # bases (post-seed batch counts) supersede it
            with self._cv:
                self._live[handle.qid] = (ex, predicates, bases)
            ids = []
            with ex:
                for b in ex.run(source):
                    handle.output.append(b)
                    ids.append(np.asarray(b.row_ids))
                    report.batches += 1
                    report.rows += b.rows
                    if handle._cancel.is_set():
                        break
            report.row_ids = (
                np.concatenate(ids) if ids else np.zeros((0,), np.int64)
            )
            snap = ex.stats_snapshot()
            report.board_predicates = tuple(
                sorted(k for k in snap if not k.startswith("_"))
            )
            report.cache_hit_rates = {
                k: v.get("cache_hit_rate", 0.0)
                for k, v in snap.items() if not k.startswith("_")
            }
            report.routing = snap.get("_routing", {})
            fsnap = snap.get("_faults", {})
            report.faults = {
                "quarantined": sorted(
                    n for n, s in fsnap.items() if s.get("quarantined")
                ),
                "unquarantined": sorted(
                    n for n, s in fsnap.items() if s.get("unquarantines")
                ),
                "failures": sum(s.get("failures", 0) for s in fsnap.values()),
                "retries": sum(s.get("retries", 0) for s in fsnap.values()),
                "passthrough_batches": sum(
                    s.get("quarantined_batches", 0) for s in fsnap.values()
                ),
                "skipped_routes": sum(
                    s.get("skipped_routes", 0) for s in fsnap.values()
                ),
            }
            report.reverify = snap.get("_service", {}).get("reverify")
            report.state = CANCELLED if handle._cancel.is_set() else DONE
        except Exception as e:
            report.state = FAILED
            report.error = repr(e)
        finally:
            if ex is not None:
                try:
                    ex.shutdown()
                except Exception:
                    pass
            finished = time.monotonic()
            report.finished_at = finished
            report.eval_time_s = finished - started
            if handle.deadline_abs is not None:
                report.deadline_met = finished <= handle.deadline_abs
            with self._cv:
                # final fold: this query's closing profile becomes the
                # next tenant's prior (then drop the live reference)
                if handle.qid in self._live:
                    ex2, preds, bases = self._live.pop(handle.qid)
                    try:
                        self.store.record_live(ex2.stats, preds, bases)
                    except Exception:
                        pass
                self._running.pop(handle.qid, None)
                if report.state == DONE:
                    self.completed += 1
                elif report.state == FAILED:
                    self.failed += 1
                elif report.state == CANCELLED:
                    self.cancelled += 1
                self._cv.notify_all()
            self.arbiter.note_query_finished(handle.qid)
            try:
                self.store.flush()
            except Exception:
                pass
            handle._done.set()

    # ----------------------------- lifecycle ----------------------------- #
    def pending_count(self) -> int:
        with self._cv:
            return len(self._pending)

    def running_count(self) -> int:
        with self._cv:
            return len(self._running)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until no query is pending or running; False on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._pending or self._running:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._cv.wait(timeout=min(
                    _DISPATCH_POLL_S, remaining or _DISPATCH_POLL_S
                ))
        return True

    def snapshot(self) -> Dict[str, object]:
        """Service-level counters + the shared arbiter's picture."""
        with self._cv:
            return {
                "submitted": self.submitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "failed": self.failed,
                "cancelled": self.cancelled,
                "expired": self.expired,
                "pending": len(self._pending),
                "running": len(self._running),
                "max_concurrent": self.max_concurrent,
                "max_pending": self.max_pending,
                "arbiter": self.arbiter.counters(),
            }

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop accepting submits; optionally wait for in-flight queries.
        With ``drain=False`` pending queries are cancelled."""
        with self._cv:
            self._closed = True
            if not drain:
                for item in self._pending:
                    self._finish_pending(item[3], CANCELLED)
                self._pending = []
            self._cv.notify_all()
        if drain:
            self.drain(timeout=timeout)
        self._dispatcher.join(timeout=5.0)
        with self._cv:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=5.0)

    def __enter__(self) -> "QueryService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


# ----------------------------- single-query CLI ----------------------------- #
def build_llm_udf(arch: str = "smollm-135m", params=None, cfg=None):
    """The LLM(...) predicate: a real decoder forward + token-pool scoring."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.udf import UDF
    from repro.data.text import FOOD_WORDS, SERVICE_WORDS
    from repro.models.registry import model_api

    cfg = cfg or get_config(arch).reduce_for_smoke()
    api = model_api(cfg)
    if params is None:
        params = api.init_params(cfg, jax.random.key(0))

    food = jnp.asarray(FOOD_WORDS)
    service = jnp.asarray(SERVICE_WORDS)

    @jax.jit
    def score(tokens):  # (rows, MAX_LEN) int32, 0-padded
        batch = {"tokens": tokens, "labels": tokens}
        from repro.models import transformer as tf

        logits = tf.forward(cfg, params, batch)  # (rows, L, V)
        mask = (tokens > 0)[..., None].astype(logits.dtype)
        pooled = (jax.nn.log_softmax(logits.astype(jnp.float32), -1) * mask).sum(1)
        return pooled[:, food].mean(-1) - pooled[:, service].mean(-1)

    def fn(data):
        return np.asarray(score(jnp.asarray(data["tokens"])))

    return UDF(
        "LLM", fn, columns=("tokens",), resource="tpu:0",
        proxy_cost=lambda d: float((d["tokens"] > 0).sum()),  # text length
    )


def review_source(reviews, chunk=64):
    for i in range(0, len(reviews), chunk):
        part = reviews[i : i + chunk]
        toks = np.zeros((len(part), MAX_LEN), np.int32)
        for j, r in enumerate(part):
            toks[j, : len(r.tokens)] = r.tokens[:MAX_LEN]
        yield {
            "tokens": toks,
            "rating": np.array([r.rating for r in part], np.int32),
            "_row_id": np.array([r.rid for r in part], np.int64),
        }


def main() -> None:
    """Single-query driver, rebuilt on QueryService (max_concurrent=1):
    the one-off path and the multi-tenant path share one implementation."""
    from repro.core.plan import Query, TrivialPredicate, batches_of
    from repro.core.policies import EDDY_POLICIES, DataAware

    ap = argparse.ArgumentParser()
    ap.add_argument("--reviews", type=int, default=200)
    ap.add_argument("--policy", default="cost", choices=sorted(EDDY_POLICIES))
    ap.add_argument("--batch-rows", type=int, default=10)
    args = ap.parse_args()

    from repro.data.text import make_reviews

    reviews = make_reviews(args.reviews)
    llm = build_llm_udf()
    pred = Predicate("LLM_is_food", llm, compare=lambda s: s > 0)
    q = Query(
        source=review_source(reviews),
        predicates=[pred],
        trivial=[TrivialPredicate("rating", "<=", 1)],
        batch_rows=args.batch_rows,
    )
    t0 = time.perf_counter()
    with QueryService(max_concurrent=1) as service:
        handle = service.submit(
            [pred], batches_of(q),
            policy=EDDY_POLICIES[args.policy](),
            laminar_policy_factory=DataAware,
            max_workers=4,
        )
        report = handle.result()
    dt = time.perf_counter() - t0
    print(f"[serve] matched {report.rows} negative food reviews in {dt:.2f}s"
          f" (queue {report.queue_time_s*1e3:.1f}ms,"
          f" eval {report.eval_time_s:.2f}s)")
    print("[serve] routing:", report.routing)
    print("[serve] cache hit rates:", report.cache_hit_rates)
    print("[serve] service:", service.snapshot())


if __name__ == "__main__":
    main()
