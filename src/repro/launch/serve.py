"""AQP serving driver: ML-predicate queries over batched requests.

This is the paper's execution kind (query processing with ML UDFs): a query
with a trivial predicate (pushed down) and an expensive LLM predicate runs
through the full Hydro pipeline — EddyPull -> central queue -> Eddy router
-> Laminar workers (GACU) -> output. The LLM predicate is a REAL (reduced)
decoder from the model zoo scoring reviews with next-token logits.

  PYTHONPATH=src python -m repro.launch.serve --reviews 200 --policy cost
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    AQPExecutor, DataAware, Predicate, Query, TrivialPredicate, UDF,
    optimize,
)
from repro.core.policies import EDDY_POLICIES
from repro.data.text import FOOD_WORDS, SERVICE_WORDS, make_reviews
from repro.models.registry import model_api

MAX_LEN = 512


def build_llm_udf(arch: str = "smollm-135m", params=None, cfg=None) -> UDF:
    """The LLM(...) predicate: a real decoder forward + token-pool scoring."""
    cfg = cfg or get_config(arch).reduce_for_smoke()
    api = model_api(cfg)
    if params is None:
        params = api.init_params(cfg, jax.random.key(0))

    food = jnp.asarray(FOOD_WORDS)
    service = jnp.asarray(SERVICE_WORDS)

    @jax.jit
    def score(tokens):  # (rows, MAX_LEN) int32, 0-padded
        batch = {"tokens": tokens, "labels": tokens}
        from repro.models import transformer as tf

        logits = tf.forward(cfg, params, batch)  # (rows, L, V)
        mask = (tokens > 0)[..., None].astype(logits.dtype)
        pooled = (jax.nn.log_softmax(logits.astype(jnp.float32), -1) * mask).sum(1)
        return pooled[:, food].mean(-1) - pooled[:, service].mean(-1)

    def fn(data):
        return np.asarray(score(jnp.asarray(data["tokens"])))

    return UDF(
        "LLM", fn, columns=("tokens",), resource="tpu:0",
        proxy_cost=lambda d: float((d["tokens"] > 0).sum()),  # text length
    )


def review_source(reviews, chunk=64):
    for i in range(0, len(reviews), chunk):
        part = reviews[i : i + chunk]
        toks = np.zeros((len(part), MAX_LEN), np.int32)
        for j, r in enumerate(part):
            toks[j, : len(r.tokens)] = r.tokens[:MAX_LEN]
        yield {
            "tokens": toks,
            "rating": np.array([r.rating for r in part], np.int32),
            "_row_id": np.array([r.rid for r in part], np.int64),
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reviews", type=int, default=200)
    ap.add_argument("--policy", default="cost", choices=sorted(EDDY_POLICIES))
    ap.add_argument("--batch-rows", type=int, default=10)
    args = ap.parse_args()

    reviews = make_reviews(args.reviews)
    llm = build_llm_udf()
    pred = Predicate("LLM_is_food", llm, compare=lambda s: s > 0)
    q = Query(
        source=review_source(reviews),
        predicates=[pred],
        trivial=[TrivialPredicate("rating", "<=", 1)],
        batch_rows=args.batch_rows,
    )
    plan = optimize(
        q,
        executor_kwargs=dict(
            policy=EDDY_POLICIES[args.policy](),
            laminar_policy_factory=DataAware,
            max_workers=4,
        ),
    )
    print("[serve] plan:", " -> ".join(plan.description))
    t0 = time.perf_counter()
    rows = plan.collect_rows()
    dt = time.perf_counter() - t0
    n = len(rows["_row_id"])
    print(f"[serve] matched {n} negative food reviews in {dt:.2f}s")
    print("[serve] stats:", plan.executor.stats_snapshot())
    print("[serve] active workers:", plan.executor.active_worker_counts())


if __name__ == "__main__":
    main()
