"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real device count.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.kernels.launch import AxisType, make_mesh


def _mk(shape, axes) -> Mesh:
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (256 chips / pod); multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(*, model_parallel: int = 1) -> Mesh:
    """Mesh over whatever devices this process actually has (tests/examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0, (n, model_parallel)
    return _mk((n // model_parallel, model_parallel), ("data", "model"))


def mesh_chip_count(mesh: Mesh) -> int:
    return int(mesh.devices.size)
