import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimbing driver (§Perf): lower a cell under named variants and
report the roofline-term deltas + per-collective-type byte breakdowns.

Each iteration in EXPERIMENTS.md §Perf is one invocation:

  PYTHONPATH=src python -m repro.launch.perf --arch arctic-480b \
      --shape decode_32k --variant baseline --variant moe_ep2d

Variants are config transforms (the code paths they enable live in the
model zoo behind config flags, so production configs can adopt them).
Results accumulate in results/perf/<cell>__<variant>.json.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_config, get_shape  # noqa: E402
from repro.launch.dryrun import compile_cell, train_overrides  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chip_count  # noqa: E402
from repro.models.registry import model_api  # noqa: E402
from repro.roofline import analysis  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "perf")


# ----------------------------- variants ----------------------------------- #
def v_baseline(cfg):
    return cfg


def v_moe_ep2d(cfg):
    """Resident-expert 2D EP at serve: experts over 'data', d_ff over
    'model' — removes the per-layer expert weight gather entirely."""
    return dataclasses.replace(cfg, moe_serve_ep2d=True)


def v_cache_fp8(cfg):
    """KV cache stored in fp8_e4m3 (halves cache reads/writes)."""
    return dataclasses.replace(cfg, cache_dtype="float8_e4m3fn")


def v_remat_dots(cfg):
    return dataclasses.replace(cfg, remat_policy="dots_no_batch")


def v_accum16(cfg):
    return dataclasses.replace(cfg, grad_accum=16)


def v_accum4(cfg):
    return dataclasses.replace(cfg, grad_accum=4)


def v_sp_accum1(cfg):
    """Sequence-parallel activations + NO grad accumulation: the residual
    stream shards seq over 'model' (16x smaller), so the global batch fits
    in one pass and the per-microbatch FSDP weight regathers disappear."""
    return dataclasses.replace(cfg, seq_parallel=True, grad_accum=0)


def v_sp_accum2(cfg):
    return dataclasses.replace(cfg, seq_parallel=True, grad_accum=2)


def v_sp_accum4(cfg):
    return dataclasses.replace(cfg, seq_parallel=True, grad_accum=4)


def v_ep2d_fp8(cfg):
    """Stacked serving optimizations: resident experts + fp8 KV cache."""
    return dataclasses.replace(cfg, moe_serve_ep2d=True,
                               cache_dtype="float8_e4m3fn")


VARIANTS = {
    "baseline": v_baseline,
    "moe_ep2d": v_moe_ep2d,
    "cache_fp8": v_cache_fp8,
    "remat_dots": v_remat_dots,
    "accum16": v_accum16,
    "accum4": v_accum4,
    "sp_accum1": v_sp_accum1,
    "sp_accum2": v_sp_accum2,
    "sp_accum4": v_sp_accum4,
    "ep2d_fp8": v_ep2d_fp8,
}


def collective_breakdown(sample):
    out = {}
    for op, rec in sample.collectives.items():
        if rec["count"]:
            out[op] = {
                "count": rec["count"],
                "wire_GB": round(rec["wire_bytes"] / 1e9, 4),
            }
    return out


def run(arch: str, shape_name: str, variant: str, *, outdir: str,
        mesh_shape=None) -> dict:
    cfg0, shape = get_config(arch), get_shape(shape_name)
    cfg = VARIANTS[variant](cfg0)
    if mesh_shape is None:
        mesh = make_production_mesh(multi_pod=False)
    else:
        from repro.kernels.launch import AxisType, make_mesh

        mesh = make_mesh(
            tuple(mesh_shape), ("data", "model"),
            axis_types=(AxisType.Auto,) * 2,
        )
    chips = mesh_chip_count(mesh)

    # full-cell compile (memory honesty: the REAL step, incl. accumulation)
    sample, times = compile_cell(cfg, shape, mesh)

    # TRUE-STEP accounting: a microbatched step repeats the whole pass —
    # including the FSDP weight gathers — per microbatch. Lower the pass at
    # the MICRO batch and scale by M (slight optimizer-update overcount,
    # documented in EXPERIMENTS.md).
    eff = train_overrides(cfg, shape)
    m = eff.grad_accum if (shape.kind == "train" and eff.grad_accum > 1) else 1
    pass_shape = (
        dataclasses.replace(shape, global_batch=shape.global_batch // m)
        if m > 1 else shape
    )
    api = model_api(cfg)
    base_cfg, units = api.roofline_units(cfg)
    base_cfg = dataclasses.replace(base_cfg, grad_accum=0)
    units = [(c, dataclasses.replace(u, grad_accum=0)) for c, u in units]
    base_s, _ = compile_cell(base_cfg, pass_shape, mesh)
    unit_s = [(c, compile_cell(u, pass_shape, mesh)[0]) for c, u in units]
    totals = analysis.delta_total(base_s, unit_s)
    totals = {k: v * m for k, v in totals.items()}
    terms = analysis.roofline_terms(totals["flops"], totals["bytes"], totals["wire"])
    terms["accum_factor"] = m
    mf = analysis.model_flops(cfg0, shape)

    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "mesh_shape": list(mesh.devices.shape),
        "terms": terms,
        "per_device": totals,
        "memory": sample.mem,
        "collectives_full_model_scan_once": collective_breakdown(sample),
        "model_flops": mf,
        "useful_ratio": mf / (totals["flops"] * chips) if totals["flops"] else 0,
        "times": times,
    }
    os.makedirs(outdir, exist_ok=True)
    tag = "" if mesh_shape is None else f"__mesh{'x'.join(map(str, mesh_shape))}"
    path = os.path.join(outdir, f"{arch}__{shape_name}__{variant}{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)

    print(f"== {arch} / {shape_name} / {variant} ==")
    print(f" compute_s={terms['compute_s']:.4g} memory_s={terms['memory_s']:.4g} "
          f"collective_s={terms['collective_s']:.4g} dominant={terms['dominant']}")
    print(f" roofline_fraction={terms['roofline_fraction']:.4f} "
          f"useful_ratio={rec['useful_ratio']:.3f}")
    print(f" temp_bytes/dev={sample.mem['temp_bytes']/1e9:.2f}GB "
          f"args/dev={sample.mem['argument_bytes']/1e9:.2f}GB")
    print(f" collectives (full model, scan-once): "
          f"{rec['collectives_full_model_scan_once']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=None,
                    choices=sorted(VARIANTS))
    ap.add_argument("--mesh-shape", default=None,
                    help="override (data,model), e.g. 256,1 for pure DP")
    ap.add_argument("--outdir", default=os.path.abspath(RESULTS))
    args = ap.parse_args()
    ms = tuple(int(x) for x in args.mesh_shape.split(",")) if args.mesh_shape else None
    for v in args.variant or ["baseline"]:
        run(args.arch, args.shape, v, outdir=args.outdir, mesh_shape=ms)


if __name__ == "__main__":
    main()
