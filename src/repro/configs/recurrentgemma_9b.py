"""RecurrentGemma-9B — Griffin-style hybrid: RG-LRU recurrent blocks + local
attention in a 2:1 pattern (attn every third block). [arXiv:2402.19427]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,           # MQA in the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    local_window=2048,
    attn_pattern=("rglru", "rglru", "local"),  # repeated; remainder = rglru
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
)
