"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,        # padded to 50432 internally
    ssm_state=128,
    ssm_expand=2,            # d_inner = 2048
    ssm_head_dim=64,         # 32 SSD heads
    ssm_conv_width=4,
    source="arXiv:2405.21060",
)
