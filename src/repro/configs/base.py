"""Config dataclasses for architectures and input shapes.

Every assigned architecture is expressed as a frozen ``ModelConfig``; input
shapes are ``ShapeConfig``. The FULL configs are only ever lowered via the
dry-run (ShapeDtypeStruct, no allocation); ``reduce_for_smoke`` derives a
tiny same-family config for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

VOCAB_PAD_MULTIPLE = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | encdec | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dense_residual: bool = False  # arctic: parallel dense FFN residual
    capacity_factor: float = 1.25

    # --- attention variants ---
    sliding_window: int = 0        # >0: mistral-style SWA (ring-buffer cache)
    local_window: int = 0          # >0: griffin-style local attention window
    attn_pattern: Tuple[str, ...] = ()  # hybrid block pattern, e.g. ("rglru","rglru","local")

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # --- enc-dec (whisper) ---
    num_encoder_layers: int = 0
    num_frames: int = 0            # stub frontend: encoder frame embeddings

    # --- VLM (llava) ---
    num_patches: int = 0           # stub frontend: patch embeddings prepended

    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    attention_impl: str = "xla"    # xla | pallas (pallas validated in interpret mode)
    attention_chunk_q: int = 512   # XLA-path q blocking (0 = dense)
    attention_unroll: bool = False  # unroll q chunks (roofline lowering only)
    remat: bool = True
    remat_policy: str = "nothing"  # nothing | dots | dots_no_batch
    grad_accum: int = 1            # gradient-accumulation microbatches
    grad_accum_dtype: str = "float32"
    tie_embeddings: bool = False
    # --- beyond-paper perf knobs (§Perf) ---
    moe_serve_ep2d: bool = False   # resident experts: E over 'data', F over 'model'
    cache_dtype: str = ""          # "" = model dtype; e.g. "float8_e4m3fn"
    seq_parallel: bool = False     # Megatron-SP: inter-block activations shard seq over 'model'
    source: str = ""               # provenance note

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if serving memory/compute is sub-quadratic in context length."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def vocab_padded(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    # ----------------------- parameter counting ----------------------- #
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for 6·N·D)."""
        from repro.models.registry import family_module

        return family_module(self.family).param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import family_module

        mod = family_module(self.family)
        if hasattr(mod, "active_param_count"):
            return mod.active_param_count(self)
        return self.param_count()

    # --------------------------- reduction ---------------------------- #
    def reduce_for_smoke(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=len(self.attn_pattern) if self.attn_pattern else 2,
            d_model=64,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=257,  # deliberately not a multiple of the pad unit
            head_dim=16 if self.num_heads else 0,
            remat=False,
            dtype="float32",  # CPU smoke: exact numerics
        )
        if self.num_experts:
            kw.update(num_experts=4, num_experts_per_tok=min(2, self.num_experts_per_tok))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
        if self.num_encoder_layers:
            kw.update(num_encoder_layers=2, num_frames=8)
        if self.num_patches:
            kw.update(num_patches=8)
        if self.sliding_window:
            kw.update(sliding_window=32)
        if self.local_window:
            kw.update(local_window=32)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch

    def reduce_for_smoke(self) -> "ShapeConfig":
        return ShapeConfig(
            name=self.name + "-smoke",
            seq_len=min(self.seq_len, 32),
            global_batch=min(self.global_batch, 2),
            kind=self.kind,
        )


SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable dry-run cell? Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped(full-attention: 500k decode needs sub-quadratic attention)"
    return True, "ok"
