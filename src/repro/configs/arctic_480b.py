"""Snowflake Arctic-480B — 128-expert top-2 MoE with a parallel dense
residual MLP. [hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    num_experts_per_tok=2,
    moe_dense_residual=True,  # dense-MoE hybrid: dense FFN residual in parallel
    rope_theta=10_000.0,
    source="hf:Snowflake/snowflake-arctic-base",
)
