"""LLaVA-NeXT-34B — VLM: dense GQA backbone; anyres tiling frontend is a STUB
(input_specs supplies precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    num_patches=2880,  # anyres: base 576 + 4 tiles x 576 (stub frontend)
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
