"""Whisper-small — encoder-decoder transformer; conv/mel frontend is a STUB
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    num_encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,          # MHA (kv == heads)
    d_ff=3072,
    vocab_size=51865,         # padded to 51968 internally (not 16-divisible)
    num_frames=1500,          # post-conv mel frame embeddings (stub frontend)
    rope_theta=10_000.0,      # learned-pos in the original; RoPE stand-in noted in DESIGN.md
    source="arXiv:2212.04356",
)
