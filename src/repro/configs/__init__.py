"""Architecture registry: the 10 assigned architectures + input shapes.

``get_config(arch)`` returns the exact published config; the dry-run iterates
``iter_cells()`` over the 40 (arch x shape) cells.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, cell_applicable

from repro.configs import (  # noqa: E402
    arctic_480b,
    grok1_314b,
    h2o_danube_1_8b,
    llama3_8b,
    llava_next_34b,
    mamba2_370m,
    recurrentgemma_9b,
    smollm_135m,
    whisper_small,
    yi_6b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        yi_6b,
        smollm_135m,
        llama3_8b,
        h2o_danube_1_8b,
        arctic_480b,
        grok1_314b,
        whisper_small,
        recurrentgemma_9b,
        llava_next_34b,
        mamba2_370m,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def list_archs():
    return list(ARCHS)


def iter_cells() -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All 40 (arch x shape) cells with applicability verdicts."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            yield cfg, shape, ok, reason


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_shape",
    "list_archs",
    "iter_cells",
    "cell_applicable",
]
