"""Submesh carving: Laminar's device allocation at mesh scale.

The paper's Laminar router assigns UDF workers to GPUs proportionally to
measured cost. At TPU scale the resource quantum is a mesh SLICE: this
module splits a mesh's data axis into per-predicate submeshes sized by the
predicates' measured costs, so concurrent UDF predicates each get a
data-parallel slice while sharing the model-parallel layout.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np
from jax.sharding import Mesh


def split_mesh_data_axis(mesh: Mesh, shares: Dict[str, float]) -> Dict[str, Mesh]:
    """Split the 'data' axis into contiguous slices ~ proportional to shares.

    Every predicate gets >= 1 data row; remainders go to the largest shares.
    """
    names = list(shares)
    axis = mesh.axis_names.index("data")
    ndata = mesh.devices.shape[axis]
    total = sum(max(s, 1e-9) for s in shares.values())
    raw = {n: max(1, int(round(shares[n] / total * ndata))) for n in names}
    # fix rounding to sum exactly to ndata
    while sum(raw.values()) > ndata:
        big = max(raw, key=raw.get)
        if raw[big] <= 1:
            break
        raw[big] -= 1
    while sum(raw.values()) < ndata:
        big = max(names, key=lambda n: shares[n] / raw[n])
        raw[big] += 1

    out: Dict[str, Mesh] = {}
    start = 0
    for n in names:
        take = raw[n]
        idx = [slice(None)] * mesh.devices.ndim
        idx[axis] = slice(start, start + take)
        sub = mesh.devices[tuple(idx)]
        out[n] = Mesh(sub, mesh.axis_names)
        start += take
    return out


def cost_shares(costs: Dict[str, float]) -> Dict[str, float]:
    """Laminar sizing rule: submesh share proportional to measured cost."""
    total = sum(costs.values()) or 1.0
    return {k: v / total for k, v in costs.items()}
