"""Fault tolerance for the training loop (DESIGN.md §5).

Mechanisms (all exercised by tests):
  * crash/restart — the train driver resumes from the newest atomic
    checkpoint (checkpoint/checkpointer.py); a FailureInjector can kill the
    step loop deterministically to prove it.
  * straggler mitigation — StepWatchdog tracks a robust step-time envelope
    (median + k*MAD); slow steps emit straggler events that the driver
    reacts to (re-dispatch / rebalance hook). This is Hydro's data-aware
    load-balancing idea applied at pod scale: the proxy signal is step
    latency instead of input size.
  * elastic rescale — checkpoints restore onto a different mesh
    (Checkpointer.restore with target shardings); ``plan_rescale`` computes
    the new mesh shape when a pod drops out.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class FailureInjector:
    """Deterministic failure schedule for tests: fail at given steps."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.failures = 0

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected failure at step {step}")


@dataclass
class StragglerEvent:
    step: int
    seconds: float
    threshold: float


@dataclass
class StepWatchdog:
    """Robust step-time envelope: flag steps slower than median + k*MAD."""

    k: float = 5.0
    window: int = 50
    min_samples: int = 5
    times: List[float] = field(default_factory=list)
    events: List[StragglerEvent] = field(default_factory=list)
    on_straggler: Optional[Callable[[StragglerEvent], None]] = None
    _step: int = 0

    def observe(self, seconds: float) -> Optional[StragglerEvent]:
        self._step += 1
        ev = None
        if len(self.times) >= self.min_samples:
            med = statistics.median(self.times)
            mad = statistics.median(abs(t - med) for t in self.times) or med * 0.05
            threshold = med + self.k * max(mad, 1e-9)
            if seconds > threshold:
                ev = StragglerEvent(self._step, seconds, threshold)
                self.events.append(ev)
                if self.on_straggler is not None:
                    self.on_straggler(ev)
        self.times.append(seconds)
        if len(self.times) > self.window:
            self.times.pop(0)
        return ev


def plan_rescale(total_chips: int, failed_chips: int, *, model_parallel: int):
    """New (data, model) mesh shape after losing ``failed_chips``.

    Keeps model_parallel fixed (weights layout unchanged) and shrinks the
    data axis to the largest multiple that fits — the elastic-scaling
    policy: DP shrinks, TP layout survives, checkpoint reshards on restore.
    """
    remaining = total_chips - failed_chips
    data = remaining // model_parallel
    if data < 1:
        raise ValueError("not enough chips for the model-parallel layout")
    return (data, model_parallel)


class Heartbeat:
    """Liveness file for external supervisors (touched every step)."""

    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int) -> None:
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}\n")
