from repro.distributed.sharding import (  # noqa: F401
    TRAIN_RULES,
    SERVE_RULES,
    Rules,
    axis_size,
    batch_axes,
    constrain,
    named_sharding,
    spec_for,
)
