"""Logical-axis sharding rules with divisibility-aware fallback.

Model code annotates every tensor dim with a *logical* name ("d_ff", "heads",
"batch", ...). ``spec_for`` resolves logical names to mesh axes through a
``Rules`` table, replicating any dim whose size does not divide the mapped
mesh axes (the GQA kv-head / grok-expert cases) — never a sharding error, by
construction.

Two standard rule sets:
  * TRAIN_RULES — FSDP x TP: weight d_model dims shard over "data"
    (ZeRO-3-style, GSPMD inserts all-gather/reduce-scatter), wide dims
    (d_ff / heads / vocab / experts) over "model"; batch over ("pod","data").
  * SERVE_RULES — TP only: weights shard over "model"; batch over
    ("pod","data"); decode KV caches shard seq over "model"
    (flash-decode partial-softmax combine, see models/attention.py).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Anything placing arrays on a mesh goes through here; installing the
# launch subsystem's jax forward-compat polyfills (make_mesh axis_types,
# AxisType, shard_map check_vma) keeps mesh construction version-portable.
import repro.kernels.launch  # noqa: F401

AxisSpec = Union[None, str, Tuple[str, ...]]


class Rules:
    def __init__(self, table: Dict[str, AxisSpec], name: str = "rules"):
        self.table = dict(table)
        self.name = name

    def get(self, logical: Optional[str]) -> AxisSpec:
        if logical is None:
            return None
        return self.table.get(logical)

    def replace(self, **kw: AxisSpec) -> "Rules":
        t = dict(self.table)
        t.update(kw)
        return Rules(t, name=self.name + "+")

    def __repr__(self):
        return f"Rules({self.name})"


TRAIN_RULES = Rules(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_sp": "model",        # sequence-parallel inter-block activations
        "d_model": None,          # activation feature dim: replicated
        "d_model_w": "data",      # weight feature dim: FSDP over data
        "attn_dw": "data",        # attention in/out feature dim (== d_model_w at train)
        "d_sharded": None,        # transient constraint: replicated at train
        "experts_data": "data",   # ep2d storage (serve-only configs)
        "expert_dw": "data",      # expert weight feature dim (FSDP)
        "heads": "model",
        "kv_heads": "model",
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "state": None,
        "ssm_heads": "model",
        "ssm_inner": "model",
        "lru": "model",
        "lru_blocks": "model",
        "frames": None,
        "patches": None,
        "cache_seq": "model",
        "window": None,
        "conv": None,
        "layers": None,           # scan-stacked leading dim
    },
    name="train(FSDPxTP)",
)

SERVE_RULES = Rules(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "seq_sp": "model",
        "d_model": None,
        "d_model_w": None,        # no FSDP at serve time: weights resident
        # attention projections of archs whose head count does NOT divide
        # the model axis (56, 12, 9 heads...) shard on the FEATURE dim at
        # serve: GBs of replicated projections become a tiny per-token psum
        # (SS Perf iteration, arctic decode args 14.8 -> ~3 GB/chip).
        "attn_dw": "model",
        "d_sharded": "model",     # transient activation constraint (out_proj)
        "experts_data": "data",   # ep2d resident-expert storage layout
        "expert_dw": "data",      # 480B experts can't be data-replicated
        "heads": "model",
        "kv_heads": "model",
        "d_ff": "model",
        "vocab": "model",
        "experts": "model",
        "state": None,
        "ssm_heads": "model",
        "ssm_inner": "model",
        "lru": "model",
        "lru_blocks": "model",
        "frames": None,
        "patches": None,
        "cache_seq": "model",     # sequence-sharded KV cache
        "window": None,
        "conv": None,
        "layers": None,
    },
    name="serve(TP)",
)


def axis_size(mesh: Mesh, axes: AxisSpec) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


def _present(mesh: Mesh, axes: AxisSpec) -> AxisSpec:
    """Drop mesh axes that do not exist on this mesh (e.g. 'pod' single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def parse_dims(logical: Union[str, Sequence[Optional[str]]]) -> Tuple[Optional[str], ...]:
    """Logical dims are space-separated strings so they stay pytree LEAVES.

    ``"layers d_model_w d_ff"`` -> ("layers", "d_model_w", "d_ff");
    ``"."`` marks a replicated dim: ``"batch . d_model"``.
    """
    if isinstance(logical, str):
        return tuple(None if t == "." else t for t in logical.split())
    return tuple(logical)


def spec_for(
    shape: Sequence[int],
    logical: Union[str, Sequence[Optional[str]]],
    rules: Rules,
    mesh: Mesh,
) -> P:
    """PartitionSpec for ``shape`` whose dims carry ``logical`` names.

    A dim is sharded over its mapped mesh axes only if its size is divisible
    by the product of those axis sizes AND no axis is claimed twice within
    the same spec; otherwise it is replicated.
    """
    logical = parse_dims(logical)
    assert len(shape) == len(logical), (shape, logical)
    out = []
    used: set = set()
    for size, name in zip(shape, logical):
        axes = _present(mesh, rules.get(name))
        if axes is None:
            out.append(None)
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a in used for a in tup):
            out.append(None)
            continue
        denom = math.prod(
            dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in tup
        )
        if denom > 1 and size % denom == 0:
            out.append(axes)
            used.update(tup)
        else:
            out.append(None)
    return P(*out)


def named_sharding(
    shape: Sequence[int],
    logical: Union[str, Sequence[Optional[str]]],
    rules: Rules,
    mesh: Mesh,
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, rules, mesh))


def batch_axes(mesh: Mesh) -> AxisSpec:
    return _present(mesh, ("pod", "data"))


def constrain(x, logical: Union[str, Sequence[Optional[str]]], rules: Rules, mesh: Mesh):
    """with_sharding_constraint by logical dim names (no-op off-mesh)."""
    try:
        spec = spec_for(x.shape, logical, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def tree_named_shardings(shapes_tree, logical_tree, rules: Rules, mesh: Mesh):
    """Map matching (ShapeDtypeStruct tree, logical-dims-string tree) -> shardings."""
    return jax.tree.map(
        lambda sds, logical: named_sharding(sds.shape, logical, rules, mesh),
        shapes_tree,
        logical_tree,
    )


def tree_shape_dtypes(shapes_tree, logical_tree, rules: Rules, mesh: Mesh):
    """Attach shardings onto a ShapeDtypeStruct tree (for .lower())."""
    def _one(sds, logical):
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=named_sharding(sds.shape, logical, rules, mesh)
        )

    return jax.tree.map(_one, shapes_tree, logical_tree)
