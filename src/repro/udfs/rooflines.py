"""Roofline-derived a-priori cost models for kernel-backed predicates.

Hydro's position (§3.3) is that UDF statistics are PROFILED at run time,
never estimated — so these analytic models are deliberately second-class:
they seed the cold-start cost prior (what a policy sees before the first
launch lands) and drive the deterministic SimClock benchmarks. Once the
executor's launch hook records real per-launch timings, the EMA overrides
everything here.

Each model is the classic roofline lower bound over the TPU-v5e chip
constants in ``repro.roofline.hw``:

    seconds(rows) = overhead + max(flops(rows) / peak_FLOP/s,
                                   bytes(rows) / HBM_bw)

FLOP/byte counts are per *predicate row* (one crop, one token sequence, one
routed token) and derived from the kernel's algorithmic shape, not from a
compiled artifact — exact HLO accounting lives in ``repro.roofline`` and
needs a lowered executable, which a cold predicate does not have yet.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.roofline import hw

# Per-launch dispatch/DMA-setup floor: keeps tiny-batch estimates from
# rounding to zero seconds, which would make a cold kernel look free.
LAUNCH_OVERHEAD_S = 5e-5

F32 = 4  # bytes


@dataclass(frozen=True)
class Roofline:
    """Analytic per-row roofline: flops/bytes scale linearly with rows."""

    flops_per_row: float
    bytes_per_row: float
    overhead_s: float = LAUNCH_OVERHEAD_S

    def seconds(self, rows: int) -> float:
        return self.overhead_s + max(
            rows * self.flops_per_row / hw.PEAK_FLOPS_BF16,
            rows * self.bytes_per_row / hw.HBM_BW,
        )

    @property
    def cost_model(self) -> Callable[[int], float]:
        """The ``UDF.cost_model`` callable (simulated seconds for N rows)."""
        return self.seconds


# --------------------------------------------------------------------------- #
# per-kernel derivations (row = one predicate input row)                      #
# --------------------------------------------------------------------------- #
def hsv_color(height: int, width: int, n_colors: int = 9) -> Roofline:
    """Row = one crop: RGB->HSV (~30 flop/px) + C range checks (8 flop each)."""
    px = height * width
    return Roofline(
        flops_per_row=px * (30 + 8 * n_colors),
        bytes_per_row=px * 3 * F32 + (n_colors + 1) * F32,
    )


def moe_router(n_experts: int, k: int = 2) -> Roofline:
    """Row = one token: softmax over E + k argmax/mask passes + renorm."""
    return Roofline(
        flops_per_row=n_experts * (10 + 4 * k),
        bytes_per_row=n_experts * F32 + 2 * k * F32,
    )


def flash_attention(seq: int, heads: int, head_dim: int,
                    causal: bool = True) -> Roofline:
    """Row = one sequence: 4*S^2*H*D matmul flops (halved when causal)."""
    flops = 4.0 * seq * seq * heads * head_dim
    if causal:
        flops /= 2
    return Roofline(
        flops_per_row=flops,
        bytes_per_row=4 * seq * heads * head_dim * F32,  # q,k,v in + out
    )


def decode_attention(seq: int, heads: int, head_dim: int,
                     kv_heads: int = 1) -> Roofline:
    """Row = one query over an S-long KV cache: 4*S*H*D flops, cache-bound."""
    return Roofline(
        flops_per_row=4.0 * seq * heads * head_dim,
        bytes_per_row=(2 * seq * kv_heads + 2 * heads) * head_dim * F32,
    )


def ssd(seq: int, heads: int, head_dim: int, state: int) -> Roofline:
    """Row = one sequence: intra-chunk duals + state updates, ~6*S*H*P*N."""
    return Roofline(
        flops_per_row=6.0 * seq * heads * head_dim * state,
        bytes_per_row=seq * heads * (head_dim + 2 * state + 1) * F32,
    )


def rglru(seq: int, width: int) -> Roofline:
    """Row = one sequence: gate activations + scan, ~12 flop per (t, w)."""
    return Roofline(
        flops_per_row=12.0 * seq * width,
        bytes_per_row=4 * seq * width * F32,  # x, r, i in + h out
    )
