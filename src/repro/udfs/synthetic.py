"""Synthetic predicates for deterministic benchmarks and examples.

Two families, both previously duplicated as ad-hoc closures across
examples/ and benchmarks/:

* ``planted_predicate`` — a pure membership filter with an ANALYTIC cost
  model (rows * cost_per_row). This is the SimClock workhorse: the UC1/UC3
  benchmarks plant ground-truth pass sets and paper-calibrated per-row
  costs, then compare routing policies on simulated makespan.

* ``planted_detector`` / ``planted_classifier`` — REAL compute (the HSV
  color kernel over the pixel column, so wall-clock cost is genuine) with
  planted labels, standing in for detector/classifier checkpoints we don't
  ship. The detector reads boolean labels indexed by ``rid``; the
  classifier reads integer labels from a batch column and passes
  ``label == target``.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

from repro.core.statstore import canonical_fingerprint
from repro.core.udf import Predicate, UDF
from repro.kernels import ops
from repro.udfs.library import block_divisor, one_row_probe
from repro.udfs import rooflines


def planted_predicate(
    name: str,
    passing_ids: Iterable[int],
    *,
    cost_per_row: float,
    resource: str = "tpu:0",
    column: str = "rid",
) -> Predicate:
    """Membership filter over ``column`` with an analytic SimClock cost."""
    ids = np.asarray(sorted(int(i) for i in passing_ids))

    udf = UDF(
        name,
        fn=lambda d: np.isin(d[column], ids),
        columns=(column,),
        resource=resource,
        cost_model=lambda rows: rows * cost_per_row,
        bucket=False,
        # planted sets are benchmark-local, so the fingerprint keys on the
        # planted NAME + cost config: re-building the same scenario in a
        # fresh process maps to the same persistent-statistics record
        fingerprint=canonical_fingerprint(
            f"planted:{name}", cost_per_row=cost_per_row, column=column),
    )
    return Predicate(name, udf, compare=lambda o: o.astype(bool))


def planted_detector(
    name: str,
    planted_mask: np.ndarray,
    *,
    work_dim: int = 96,
    impl: str = "pallas",
    resource: str = "tpu:0",
) -> Predicate:
    """Detector stand-in: real HSV-kernel compute + planted boolean labels.

    The ``frame`` column supplies the pixels (any layout reshapeable to
    (rows, work_dim, work_dim, 3)); ``rid`` indexes the planted labels.
    With the default ``impl="pallas"`` every evaluation is a real kernel
    launch, so an executor's launch hook sees genuine per-launch cost."""
    planted = np.asarray(planted_mask).astype(bool)
    block_rows = block_divisor(work_dim, 64)

    def fn(d):
        ops.hsv_color_classify(
            np.asarray(d["frame"], np.float32).reshape(
                -1, work_dim, work_dim, 3
            ),
            impl=impl, block_rows=block_rows,
        )
        return planted[d["rid"]]

    udf = UDF(
        name, fn, columns=("frame", "rid"), resource=resource, bucket=False,
        warm_fn=one_row_probe(
            fn, {"frame": (work_dim, work_dim, 3), "rid": ()},
            {"frame": np.float32, "rid": np.int64},
        ),
        cost_model=rooflines.hsv_color(work_dim, work_dim).cost_model,
        proxy_cost=lambda d: float(np.asarray(d["frame"]).size),
    )
    return Predicate(name, udf, compare=lambda o: o.astype(bool))


def planted_classifier(
    name: str,
    target: int,
    *,
    label_column: str,
    pixel_column: str = "crop",
    impl: str = "xla",
    resource: str = "tpu:0",
) -> Predicate:
    """Classifier stand-in: real HSV compute over (B, H, W, 3) pixels +
    planted integer labels read from ``label_column``; passes label ==
    ``target``. ``impl="xla"`` burns real XLA compute without claiming a
    kernel launch (a ViT stand-in, not the color kernel itself)."""

    def fn(d):
        px = np.asarray(d[pixel_column], np.float32)
        ops.hsv_color_classify(px, impl=impl,
                               block_rows=block_divisor(px.shape[1], 64))
        return np.asarray(d[label_column])

    udf = UDF(
        name, fn, columns=(pixel_column, label_column), resource=resource,
        proxy_cost=lambda d: float(np.asarray(d[pixel_column]).size),
    )
    return Predicate(name, udf, compare=lambda o: o == target)
