"""repro.udfs — the kernel-backed predicate library (Hydro §3.3 + §5.1).

This package closes the loop the ROADMAP called out: Pallas kernels become
first-class ``Predicate``s whose per-launch timings feed the SAME
``StatsBoard.record_eval`` path the eddy routing policies rank on.
``AQPExecutor.run()`` registers ``launch.connect_stats_board`` for the
lifetime of a run, so any predicate built here reports kernel cost under
the kernel's launch name, alongside its predicate-level stats — profiled,
never estimated.

Layout
------
``library``   six kernel predicate builders + the ``KERNEL_PREDICATES``
              registry (hsv_color, moe_router, ssd, rglru,
              flash_attention, decode_attention)
``rooflines`` analytic roofline cost priors (cold-start / SimClock only)
``synthetic`` planted predicates for deterministic benchmarks

Registering a new kernel predicate
----------------------------------
1. Launch the kernel through ``repro.kernels.launch.pallas_call`` with a
   unique ``name=`` and an honest ``rows=`` — that name is the StatsBoard
   entry every launch reports under, and rows is what cost-per-row divides
   by.
2. Write a builder returning a ``Predicate`` whose UDF sets:
   ``warm_fn`` (one-row probe, so GACU activation pays compile cost once),
   ``cost_model`` (a ``rooflines.Roofline.cost_model`` prior),
   ``proxy_cost`` (data-aware load units for Laminar balancing), and keeps
   ``bucket=True`` unless the kernel is shape-polymorphic.
3. ``register_kernel_predicate("<launch name>", builder)`` — then
   ``build_predicate("<launch name>", **kwargs)`` works anywhere, and the
   integration suite (tests/test_kernel_udfs.py) exercises it for free if
   added to its scenario table.
"""
from repro.udfs.library import (  # noqa: F401
    KERNEL_PREDICATES,
    attention_scorer_predicate,
    build_predicate,
    color_predicate,
    decode_relevance_predicate,
    register_kernel_predicate,
    rglru_gate_predicate,
    ssd_scorer_predicate,
    topic_router_predicate,
)
from repro.udfs.synthetic import (  # noqa: F401
    planted_classifier,
    planted_detector,
    planted_predicate,
)
