"""Kernel-backed predicate builders: one per Pallas kernel in the repo.

Every builder returns a first-class ``Predicate`` whose UDF

  * launches the real kernel through ``repro.kernels.launch.pallas_call``
    (compiled on TPU, interpreter elsewhere) so per-launch timings flow
    into the executor's StatsBoard via ``connect_stats_board``;
  * pre-compiles in ``warm_fn`` — GACU lazy activation (§5.1): the first
    batch routed to a worker pays compile cost, not every policy probe;
  * carries a roofline-derived ``cost_model`` prior
    (``repro.udfs.rooflines``) for SimClock runs and cold-start ranking;
  * declares a data-aware ``proxy_cost`` (crop pixels / live tokens) for
    the Laminar data-balancing policy;
  * keeps ``bucket=True`` so row counts quantize to powers of two and a
    handful of executables serve any batch (§5.1's recompilation answer);
  * carries a canonical ``fingerprint`` (kernel name + every config knob
    that changes the predicate's decision, incl. the compare target, +
    cost-model version — ``core/statstore.canonical_fingerprint``) so the
    persistent StatsStore warm-starts the same predicate across processes
    and never conflates two configurations of one kernel.

Text-consuming kernels (moe_router, ssd, rglru, flash/decode attention)
share a deterministic seeded featurizer: token ids index fixed embedding
tables (row 0 = padding = zeros), so the predicate is a pure function of
the ``tokens`` column and an oracle can re-evaluate it exactly.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax.numpy as jnp
import numpy as np

from repro.core.statstore import canonical_fingerprint
from repro.core.udf import Predicate, UDF
from repro.kernels import ops, ref
from repro.udfs import rooflines


# --------------------------------------------------------------------------- #
# featurizer helpers                                                          #
# --------------------------------------------------------------------------- #
def _embed_table(rng: np.random.Generator, vocab: int, dim: int) -> jnp.ndarray:
    """Fixed random embedding table; row 0 (padding) embeds to zero."""
    t = rng.standard_normal((vocab, dim)).astype(np.float32) / np.sqrt(dim)
    t[0] = 0.0
    return jnp.asarray(t)


def _pad_tokens(tokens: np.ndarray, seq: int) -> np.ndarray:
    """(B, L) int tokens -> (B, seq): truncate or zero-pad the time axis."""
    toks = np.asarray(tokens)
    b, length = toks.shape
    if length == seq:
        return toks.astype(np.int32)
    out = np.zeros((b, seq), np.int32)
    out[:, : min(length, seq)] = toks[:, :seq]
    return out


def block_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= cap (kernel block constraint)."""
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1


def _token_proxy(d: Dict[str, np.ndarray]) -> float:
    """Data-aware load: live (non-pad) tokens, the paper's input-size proxy."""
    return float((np.asarray(d["tokens"]) > 0).sum())


def one_row_probe(fn: Callable, columns: Dict[str, tuple],
                  dtypes: Dict[str, np.dtype]) -> Callable[[], object]:
    """GACU ``warm_fn``: run the kernel once on a single synthesized row.

    Returns the probe output so ``UDF.ensure_ready`` learns the output
    dtype/shape from the warm launch — zero-row batches then need no probe
    launch of their own."""

    def warm():
        return fn(
            {c: np.zeros((1,) + shape, dtypes[c])
             for c, shape in columns.items()}
        )

    return warm


# --------------------------------------------------------------------------- #
# builders                                                                    #
# --------------------------------------------------------------------------- #
def color_predicate(
    color: str = "black",
    *,
    size: int = 64,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """HSV color classifier over ``crop`` (B, size, size, 3) RGB [0,255].

    The paper's DogColorClassifier: kernel-fused RGB->HSV + range bucketing
    + histogram argmax; passes rows whose dominant color == ``color``."""
    target = ref.COLOR_NAMES.index(color)
    block_rows = block_divisor(size, 64)

    def fn(d):
        crops = jnp.asarray(np.asarray(d["crop"], np.float32))
        _, label = ops.hsv_color_classify(crops, impl=impl,
                                          block_rows=block_rows)
        return np.asarray(label)

    name = name or f"color_is_{color}"
    udf = UDF(
        name, fn, columns=("crop",), resource=resource,
        warm_fn=one_row_probe(fn, {"crop": (size, size, 3)},
                               {"crop": np.float32}),
        cost_model=rooflines.hsv_color(size, size).cost_model,
        proxy_cost=lambda d: float(np.asarray(d["crop"]).size),
        fingerprint=canonical_fingerprint(
            "hsv_color", color=color, size=size, impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o == target)


def topic_router_predicate(
    expert: int = 0,
    *,
    n_experts: int = 8,
    k: int = 2,
    dim: int = 16,
    vocab: int = 256,
    seq: int = 64,
    seed: int = 0,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """MoE top-k gate over mean-pooled token embeddings (``tokens`` column).

    Passes rows whose top-1 expert == ``expert`` — content routing as a
    predicate, with the fused moe_router kernel doing the gating."""
    rng = np.random.default_rng(seed)
    emb = _embed_table(rng, vocab, dim)
    w_gate = jnp.asarray(
        rng.standard_normal((dim, n_experts)).astype(np.float32) / np.sqrt(dim)
    )

    def fn(d):
        toks = _pad_tokens(d["tokens"], seq)
        x = emb[jnp.asarray(toks)]                          # (B, S, dim)
        live = jnp.maximum((jnp.asarray(toks) > 0).sum(1, keepdims=True), 1)
        logits = (x.sum(1) / live) @ w_gate                 # (B, E)
        _, idx = ops.moe_topk_router(logits, k, impl=impl)
        return np.asarray(idx[:, 0])

    name = name or f"routes_to_expert{expert}"
    udf = UDF(
        name, fn, columns=("tokens",), resource=resource,
        warm_fn=one_row_probe(fn, {"tokens": (seq,)}, {"tokens": np.int32}),
        cost_model=rooflines.moe_router(n_experts, k).cost_model,
        proxy_cost=_token_proxy,
        fingerprint=canonical_fingerprint(
            "moe_router", expert=expert, n_experts=n_experts, k=k, dim=dim,
            vocab=vocab, seq=seq, seed=seed, impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o == expert)


def ssd_scorer_predicate(
    threshold: float = 0.0,
    *,
    seq: int = 64,
    heads: int = 2,
    head_dim: int = 4,
    state: int = 4,
    vocab: int = 256,
    seed: int = 1,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """Mamba-2 SSD sequence scorer over ``tokens``; passes score > threshold.

    Token embeddings drive x/B/C; dt gates off padding (dt=0 there, so pads
    never update the state). Score = mean of the scanned output."""
    rng = np.random.default_rng(seed)
    emb_x = _embed_table(rng, vocab, heads * head_dim)
    emb_b = _embed_table(rng, vocab, state)
    emb_c = _embed_table(rng, vocab, state)
    A = -np.abs(rng.standard_normal(heads)).astype(np.float32)
    chunk = block_divisor(seq, 64)

    def fn(d):
        toks = _pad_tokens(d["tokens"], seq)
        jt = jnp.asarray(toks)
        b = toks.shape[0]
        x = emb_x[jt].reshape(b, seq, heads, head_dim)
        dt = jnp.repeat(((jt > 0) * 0.1).astype(jnp.float32)[..., None],
                        heads, axis=-1)                     # (B, S, H)
        Bm = emb_b[jt].reshape(b, seq, 1, state)
        Cm = emb_c[jt].reshape(b, seq, 1, state)
        y, _ = ops.ssd(x, dt, jnp.asarray(A), Bm, Cm, impl=impl, chunk=chunk)
        return np.asarray(y.mean(axis=(1, 2, 3)))

    name = name or "ssd_score_pos"
    udf = UDF(
        name, fn, columns=("tokens",), resource=resource,
        warm_fn=one_row_probe(fn, {"tokens": (seq,)}, {"tokens": np.int32}),
        cost_model=rooflines.ssd(seq, heads, head_dim, state).cost_model,
        proxy_cost=_token_proxy,
        fingerprint=canonical_fingerprint(
            "ssd", threshold=threshold, seq=seq, heads=heads,
            head_dim=head_dim, state=state, vocab=vocab, seed=seed,
            impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o > threshold)


def rglru_gate_predicate(
    threshold: float = 0.0,
    *,
    seq: int = 64,
    width: int = 16,
    vocab: int = 256,
    seed: int = 2,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """RG-LRU recurrent scorer over ``tokens``: final-state mean > threshold."""
    rng = np.random.default_rng(seed)
    emb_x = _embed_table(rng, vocab, width)
    emb_r = _embed_table(rng, vocab, width)
    emb_i = _embed_table(rng, vocab, width)
    a_param = jnp.asarray(rng.standard_normal(width).astype(np.float32))
    block_s = block_divisor(seq, 256)

    def fn(d):
        toks = _pad_tokens(d["tokens"], seq)
        jt = jnp.asarray(toks)
        _, h_last = ops.rglru(emb_x[jt], emb_r[jt], emb_i[jt], a_param,
                              impl=impl, block_s=block_s)
        return np.asarray(h_last.mean(-1))

    name = name or "rglru_gate_pos"
    udf = UDF(
        name, fn, columns=("tokens",), resource=resource,
        warm_fn=one_row_probe(fn, {"tokens": (seq,)}, {"tokens": np.int32}),
        cost_model=rooflines.rglru(seq, width).cost_model,
        proxy_cost=_token_proxy,
        fingerprint=canonical_fingerprint(
            "rglru", threshold=threshold, seq=seq, width=width, vocab=vocab,
            seed=seed, impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o > threshold)


def attention_scorer_predicate(
    threshold: float = 0.0,
    *,
    seq: int = 32,
    heads: int = 2,
    head_dim: int = 8,
    vocab: int = 256,
    seed: int = 3,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """Causal flash-attention scorer over ``tokens``: output mean > threshold."""
    rng = np.random.default_rng(seed)
    emb_q = _embed_table(rng, vocab, heads * head_dim)
    emb_k = _embed_table(rng, vocab, heads * head_dim)
    emb_v = _embed_table(rng, vocab, heads * head_dim)

    def fn(d):
        toks = _pad_tokens(d["tokens"], seq)
        jt = jnp.asarray(toks)
        b = toks.shape[0]
        shape = (b, seq, heads, head_dim)
        out = ops.flash_attention(
            emb_q[jt].reshape(shape), emb_k[jt].reshape(shape),
            emb_v[jt].reshape(shape),
            causal=True, impl=impl, block_q=seq, block_k=seq,
        )
        return np.asarray(out.mean(axis=(1, 2, 3)))

    name = name or "attn_score_pos"
    udf = UDF(
        name, fn, columns=("tokens",), resource=resource,
        warm_fn=one_row_probe(fn, {"tokens": (seq,)}, {"tokens": np.int32}),
        cost_model=rooflines.flash_attention(seq, heads, head_dim).cost_model,
        proxy_cost=_token_proxy,
        fingerprint=canonical_fingerprint(
            "flash_attention", threshold=threshold, seq=seq, heads=heads,
            head_dim=head_dim, vocab=vocab, seed=seed, impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o > threshold)


def decode_relevance_predicate(
    threshold: float = 0.0,
    *,
    seq: int = 32,
    heads: int = 2,
    head_dim: int = 8,
    kv_heads: int = 1,
    vocab: int = 256,
    seed: int = 4,
    impl: str = "pallas",
    resource: str = "tpu:0",
    name: str = None,
) -> Predicate:
    """Decode-attention relevance over ``tokens``: a fixed query attends the
    row's token KV cache (true lengths mask padding); mean > threshold."""
    rng = np.random.default_rng(seed)
    emb_k = _embed_table(rng, vocab, kv_heads * head_dim)
    emb_v = _embed_table(rng, vocab, kv_heads * head_dim)
    query = jnp.asarray(
        rng.standard_normal((heads, head_dim)).astype(np.float32)
    )

    def fn(d):
        toks = _pad_tokens(d["tokens"], seq)
        jt = jnp.asarray(toks)
        b = toks.shape[0]
        kc = emb_k[jt].reshape(b, seq, kv_heads, head_dim)
        vc = emb_v[jt].reshape(b, seq, kv_heads, head_dim)
        q = jnp.broadcast_to(query, (b, heads, head_dim))
        lengths = jnp.asarray(
            np.maximum((toks > 0).sum(1), 1).astype(np.int32)
        )
        out = ops.decode_attention(q, kc, vc, lengths, impl=impl, block_k=seq)
        return np.asarray(out.mean(axis=(1, 2)))

    name = name or "decode_relevance_pos"
    udf = UDF(
        name, fn, columns=("tokens",), resource=resource,
        warm_fn=one_row_probe(fn, {"tokens": (seq,)}, {"tokens": np.int32}),
        cost_model=rooflines.decode_attention(
            seq, heads, head_dim, kv_heads).cost_model,
        proxy_cost=_token_proxy,
        fingerprint=canonical_fingerprint(
            "decode_attention", threshold=threshold, seq=seq, heads=heads,
            head_dim=head_dim, kv_heads=kv_heads, vocab=vocab, seed=seed,
            impl=impl),
    )
    return Predicate(name, udf, compare=lambda o: o > threshold)


# --------------------------------------------------------------------------- #
# registry                                                                    #
# --------------------------------------------------------------------------- #
# kernel launch name (what StatsBoard entries report under) -> builder
KERNEL_PREDICATES: Dict[str, Callable[..., Predicate]] = {
    "hsv_color": color_predicate,
    "moe_router": topic_router_predicate,
    "ssd": ssd_scorer_predicate,
    "rglru": rglru_gate_predicate,
    "flash_attention": attention_scorer_predicate,
    "decode_attention": decode_relevance_predicate,
}


def register_kernel_predicate(kernel: str,
                              builder: Callable[..., Predicate]) -> None:
    """Register a builder under its kernel's launch name (see __init__)."""
    if kernel in KERNEL_PREDICATES:
        raise ValueError(f"kernel predicate {kernel!r} already registered")
    KERNEL_PREDICATES[kernel] = builder


def build_predicate(kernel: str, **kwargs) -> Predicate:
    """Instantiate the registered builder for ``kernel``."""
    try:
        builder = KERNEL_PREDICATES[kernel]
    except KeyError:
        raise KeyError(
            f"no kernel predicate registered for {kernel!r}; "
            f"known: {sorted(KERNEL_PREDICATES)}"
        ) from None
    return builder(**kwargs)
