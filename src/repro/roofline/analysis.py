"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / ICI_link_bw

Calibrated facts driving the method (measured in this container, JAX 0.8.2 /
XLA CPU backend): ``compiled.cost_analysis()`` reports PER-DEVICE numbers and
counts a ``lax.scan`` body ONCE (not x trip count). Therefore exact totals
come from DELTA LOWERING: each family exposes ``roofline_units(cfg)`` =
(base_cfg, [(count_i, unit_cfg_i)]); lowering base and unit configs gives

  total = cost(base) + sum_i count_i * (cost(unit_i) - cost(base))

The same delta handles collectives inside scan bodies. Collective wire bytes
are parsed from the per-device HLO text (result-shape bytes, replica-group
size aware) with ring-algorithm multipliers:

  all-reduce        2 * R * (n-1)/n      (reduce-scatter + all-gather ring)
  all-gather        R * (n-1)/n          (R = gathered result)
  reduce-scatter    R * (n-1)            (input = n*R)
  all-to-all        R * (n-1)/n
  collective-permute R
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.roofline import hw

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [G, S] <= [N]: G groups of size S
        return int(m.group(2))
    return default


def wire_multiplier(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def parse_collectives(hlo_text: str, default_group: int) -> Dict[str, Dict[str, float]]:
    """Per collective type: op count, result bytes, ring wire bytes/device."""
    out: Dict[str, Dict[str, float]] = {
        op: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0}
        for op in COLLECTIVE_OPS
    }
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str = m.group(1) or m.group(2)
        op = m.group(3)
        rb = _shape_bytes(type_str)
        n = _group_size(line, default_group)
        rec = out[op]
        rec["count"] += 1
        rec["result_bytes"] += rb
        rec["wire_bytes"] += rb * wire_multiplier(op, n)
    return out


def total_wire_bytes(colls: Dict[str, Dict[str, float]]) -> float:
    return sum(v["wire_bytes"] for v in colls.values())


# --------------------------------------------------------------------------- #
@dataclass
class CostSample:
    """What one lower+compile yields."""

    flops: float = 0.0                 # per device, scan-body-once
    bytes_accessed: float = 0.0        # per device, scan-body-once
    wire_bytes: float = 0.0            # per device, scan-body-once
    collectives: Dict = field(default_factory=dict)
    mem: Dict = field(default_factory=dict)
    compile_seconds: float = 0.0

    @staticmethod
    def from_compiled(compiled, default_group: int, compile_seconds: float = 0.0):
        from repro.kernels.launch import cost_analysis_dict

        ca = cost_analysis_dict(compiled)
        colls = parse_collectives(compiled.as_text(), default_group)
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        }
        return CostSample(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            wire_bytes=total_wire_bytes(colls),
            collectives=colls,
            mem=mem,
            compile_seconds=compile_seconds,
        )


def delta_total(base: CostSample, units) -> Dict[str, float]:
    """units: list of (count, CostSample). Returns corrected totals/device."""
    flops = base.flops
    byts = base.bytes_accessed
    wire = base.wire_bytes
    for count, u in units:
        flops += count * (u.flops - base.flops)
        byts += count * (u.bytes_accessed - base.bytes_accessed)
        wire += count * (u.wire_bytes - base.wire_bytes)
    return {"flops": max(flops, 0.0), "bytes": max(byts, 0.0), "wire": max(wire, 0.0)}


def roofline_terms(flops_dev: float, bytes_dev: float, wire_dev: float) -> Dict[str, float]:
    compute = flops_dev / hw.PEAK_FLOPS_BF16
    memory = bytes_dev / hw.HBM_BW
    coll = wire_dev / hw.ICI_BW_PER_LINK
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    bound = max(compute, memory, coll)
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode."""
    from repro.models.registry import model_api

    n_active = model_api(cfg).active_param_count(cfg)
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    return 2.0 * n_active * shape.global_batch
