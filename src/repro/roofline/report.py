"""Markdown report generation for EXPERIMENTS.md from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = [
    "yi-6b", "smollm-135m", "llama3-8b", "h2o-danube-1.8b", "arctic-480b",
    "grok-1-314b", "whisper-small", "recurrentgemma-9b", "llava-next-34b",
    "mamba2-370m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str) -> Dict[str, dict]:
    out = {}
    for f in glob.glob(os.path.join(dirname, "*.json")):
        r = json.load(open(f))
        out[f"{r['arch']}__{r['shape']}__{r.get('mesh', r.get('variant'))}"] = r
    return out


def _gb(x) -> str:
    return f"{x/1e9:.2f}"


def dryrun_table(records: Dict[str, dict]) -> List[str]:
    lines = [
        "| arch | shape | mesh | status | bytes/device (arg+temp) GB | "
        "flops/dev (scan-once) | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("singlepod", "multipod"):
                r = records.get(f"{arch}__{shape}__{mesh}")
                if r is None:
                    continue
                if r["status"] != "ok":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | {r['status']}: "
                        f"{r.get('reason', r.get('error', ''))[:60]} | — | — | — |"
                    )
                    continue
                mem = r["memory"]
                colls = ", ".join(
                    f"{k}:{v['count']}" for k, v in r["collectives"].items()
                    if v["count"]
                ) or "none"
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{_gb(mem['argument_bytes'])}+{_gb(mem['temp_bytes'])} | "
                    f"{r['per_device']['flops_scan_once']:.3g} | {colls} |"
                )
    return lines


def roofline_table(records: Dict[str, dict]) -> List[str]:
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant "
        "| roofline fraction | MODEL_FLOPS | useful ratio | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = records.get(f"{arch}__{shape}__singlepod")
            if r is None or "roofline" not in r:
                continue
            t = r["roofline"]["terms"]
            lever = LEVERS.get((arch, shape)) or LEVERS.get(
                ("*", t["dominant"]), ""
            )
            lines.append(
                f"| {arch} | {shape} | {t['compute_s']:.3g} | {t['memory_s']:.3g} "
                f"| {t['collective_s']:.3g} | {t['dominant'].replace('_s','')} "
                f"| {t['roofline_fraction']:.3f} | {r['roofline']['model_flops']:.3g} "
                f"| {r['roofline']['useful_ratio']:.3f} | {lever} |"
            )
    return lines


# one-sentence "what would move the dominant term down", per cell
LEVERS = {
    ("*", "memory_s"): "fuse/bf16 intermediates; shrink recompute traffic (remat policy)",
    ("*", "collective_s"): "reshard to cut gathers; overlap collectives with compute",
    ("smollm-135m", "train_4k"): "model axis wasted on a 135M model: drop TP to 1, pure DP",
    ("whisper-small", "train_4k"): "12 heads %% 16 replicate attention: use TP=4 submesh",
    ("arctic-480b", "decode_32k"): "resident-expert ep2d kills per-layer weight gather (DONE, SS Perf)",
    ("grok-1-314b", "train_4k"): "fewer microbatches => fewer FSDP regathers (SS Perf)",
    ("llama3-8b", "decode_32k"): "fp8 cache + row-wise DUS (DONE, SS Perf)",
    ("mamba2-370m", "train_4k"): "370M model over-sharded: TP=1; state dims replicated",
}


def main() -> None:
    d = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")
    recs = load(os.path.abspath(d))
    print("\n".join(dryrun_table(recs)))
    print()
    print("\n".join(roofline_table(recs)))


if __name__ == "__main__":
    main()
