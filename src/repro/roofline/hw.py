"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s per link (per the assignment)

CHIP = {
    "peak_flops_bf16": PEAK_FLOPS_BF16,
    "hbm_bw": HBM_BW,
    "ici_bw_per_link": ICI_BW_PER_LINK,
}
