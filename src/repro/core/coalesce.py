"""Adaptive micro-batch coalescing (§5.1 utilization, GRACEFUL cost shape).

Hydro's bottleneck argument is UDF evaluation throughput: accelerator
utilization per invocation is what the executor must maximize.  A stream of
tiny routing batches defeats that — every batch pays the per-launch
dispatch/trace/probe overhead and pads up to its own power-of-two bucket.
GRACEFUL-style learned UDF cost models show per-invocation cost decomposes
into a FIXED launch term plus a MARGINAL per-row term; this module turns
that decomposition into a fusing decision:

    cost(rows) ~= fixed + marginal * rows
    per-row launch share at r rows = fixed / r
    amortized once fixed / r <= amortize_eps * marginal
    =>  target_rows = fixed / (amortize_eps * marginal)

A worker that dequeues a batch asks its predicate's ``CoalescePlanner``
for a ``FusePlan``; when the plan's ``target_rows`` exceeds the batch it
drains more queued batches (non-blocking first, then waiting up to the
latency budget) and evaluates the fused batch through the normal
cache-probe -> bucketed-launch -> mask pipeline ONCE (see
``core/worker.evaluate_fused``).

Evidence, in priority order:

1. the ONLINE decomposition fitted from observed per-launch timings
   (``PredicateStats.launch_decomposition`` — refined as fused launches
   create row-count spread);
2. a SEED probed from the predicate's a-priori cost model (the
   ``udfs/rooflines.py`` priors expose exactly ``overhead + per-row``:
   ``cost_model(0)`` is the fixed term, ``cost_model(1) - cost_model(0)``
   the marginal term).

WHEN ADAPTIVE MODE DECLINES TO FUSE: with neither evidence source
available (no cost model, no fitted decomposition yet) the planner
passes batches through untouched — coalescing must never speculate on a
predicate it knows nothing about.  It also declines when the computed
target does not exceed the rows already in hand: an expensive predicate
whose per-row work dwarfs its launch overhead is already saturated, and
fusing it would only add queueing latency.  ``fixed == 0`` (no overhead
to amortize) declines too.

Fixed-k mode skips the model entirely and fuses up to ``k`` batches per
launch (row-capped) — the ablation baseline for the adaptive policy.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Optional

# Fuse until the per-row launch share drops to this fraction of the
# marginal per-row cost (0.25 => launch overhead <= 20% of total time).
AMORTIZE_EPS = 0.25

# Defaults for the executor's ``coalesce=`` knob.
DEFAULT_MAX_BATCHES = 8      # max original batches fused into one launch
DEFAULT_MAX_ROWS = 1024      # hard row cap on a fused batch
DEFAULT_MAX_WAIT_S = 0.002   # latency budget waiting for more batches

# A worker queue this deep keeps enough batches in hand to fuse; the
# executor raises the default worker queue capacity to this when
# coalescing is enabled (explicit ``worker_queue_capacity`` wins).
COALESCE_QUEUE_CAPACITY = 8


@dataclass(frozen=True)
class CoalesceConfig:
    """Resolved form of the executor's ``coalesce=`` knob.

    mode: "adaptive" (learned target), "fixed" (always fuse up to ``k``),
    or "off".  ``max_wait_s`` bounds how long a worker holding fewer than
    ``target_rows`` waits for more batches — the latency cost of fusing is
    explicit and capped.  Under SimClock the wait is forced to zero
    (wall-clock waits are meaningless in virtual time): only batches
    already queued fuse."""

    mode: str = "adaptive"
    k: int = DEFAULT_MAX_BATCHES
    max_rows: int = DEFAULT_MAX_ROWS
    max_wait_s: float = DEFAULT_MAX_WAIT_S
    amortize_eps: float = AMORTIZE_EPS

    def __post_init__(self):
        if self.mode not in ("off", "fixed", "adaptive"):
            raise ValueError(f"coalesce mode must be off|fixed|adaptive, "
                             f"got {self.mode!r}")
        if self.k < 2 and self.mode != "off":
            raise ValueError(f"coalesce k must be >= 2, got {self.k}")

    @classmethod
    def resolve(cls, spec) -> Optional["CoalesceConfig"]:
        """Normalize the executor knob: None/"off"/0/False -> None (no
        coalescing); "adaptive" -> adaptive defaults; "fixed" -> fixed-k
        defaults; an int k -> fixed-k; a CoalesceConfig passes through."""
        if spec is None or spec is False or spec == "off" or spec == 0:
            return None
        if isinstance(spec, cls):
            return None if spec.mode == "off" else spec
        if spec == "adaptive" or spec is True:
            return cls(mode="adaptive")
        if spec == "fixed":
            return cls(mode="fixed")
        if isinstance(spec, int):
            return cls(mode="fixed", k=spec)
        raise ValueError(
            f"coalesce must be None, 'off', 'fixed', 'adaptive', an int k, "
            f"or a CoalesceConfig; got {spec!r}"
        )


@dataclass(frozen=True)
class FusePlan:
    """One dequeue's fusing budget: drain until ``target_rows`` rows or
    ``max_batches`` batches are in hand, waiting at most ``max_wait_s``."""

    target_rows: int
    max_batches: int
    max_wait_s: float


class CoalescePlanner:
    """Per-predicate fusing decisions; shared by that predicate's workers.

    Thread-safe: the only mutable state is the observability counters
    (guarded by a small lock); the estimate reads fold the stats entry's
    own synchronization."""

    def __init__(self, pred, stats_entry, config: CoalesceConfig, *,
                 wall_clock: bool = True):
        self.pred = pred
        self.stats_entry = stats_entry
        self.config = config
        # SimClock: wall-clock waiting is meaningless in virtual time —
        # fuse only what is already queued (deterministic paths stay
        # coalescing-free by default anyway; this governs explicit opt-in)
        self.max_wait_s = config.max_wait_s if wall_clock else 0.0
        self._seed = self._seed_from_cost_model(pred.udf.cost_model)
        self._lock = threading.Lock()
        self.plans = 0      # dequeues that got a fuse plan
        self.declines = 0   # dequeues passed through untouched
        self.fused = 0      # launches that actually fused >= 2 batches

    # ------------------------- evidence ------------------------- #
    @staticmethod
    def _seed_from_cost_model(cost_model):
        """(fixed, marginal) probed from an a-priori cost model, or None.

        ``cost_model(0)`` is the launch-overhead intercept and
        ``cost_model(1) - cost_model(0)`` the per-row slope — exact for
        the affine ``udfs/rooflines.py`` priors, a tangent-at-one-row
        approximation otherwise.  Data-aware models (which require the
        batch payload) and models that reject ``rows=0`` yield no seed."""
        if cost_model is None:
            return None
        try:
            f0 = float(cost_model(0))
            f1 = float(cost_model(1))
        except Exception:
            return None
        if not (math.isfinite(f0) and math.isfinite(f1)):
            return None
        return max(f0, 0.0), max(f1 - f0, 0.0)

    def estimate(self):
        """Best available (fixed, marginal): online fit, else seed."""
        fitted = self.stats_entry.launch_decomposition()
        return fitted if fitted is not None else self._seed

    # ------------------------- decisions ------------------------- #
    def target_rows(self) -> Optional[int]:
        """Adaptive fuse target in rows, or None to decline (see module
        docstring for the decline conditions)."""
        cfg = self.config
        est = self.estimate()
        if est is None:
            return None
        fixed, marginal = est
        if fixed <= 0.0:
            return None  # no launch overhead to amortize
        if marginal <= 0.0:
            # pure fixed-cost launch: every fused row is free — cap-bound
            return cfg.max_rows
        return int(min(cfg.max_rows, fixed / (cfg.amortize_eps * marginal)))

    def plan(self, first_rows: int) -> Optional[FusePlan]:
        """Fusing budget for a dequeue holding ``first_rows`` rows, or
        None to pass the batch through uncoalesced."""
        cfg = self.config
        if cfg.mode == "fixed":
            with self._lock:
                self.plans += 1
            return FusePlan(cfg.max_rows, cfg.k, self.max_wait_s)
        target = self.target_rows()
        if target is None or target <= first_rows:
            with self._lock:
                self.declines += 1
            return None
        with self._lock:
            self.plans += 1
        return FusePlan(target, cfg.k, self.max_wait_s)

    def note_fused(self, n_batches: int) -> None:
        if n_batches > 1:
            with self._lock:
                self.fused += 1

    def counters(self):
        with self._lock:
            return {"plans": self.plans, "declines": self.declines,
                    "fused": self.fused}
