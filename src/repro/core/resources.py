"""Elastic resource arbiter (§5.2): cross-predicate worker leasing.

Hydro "dynamically allocates resources for evaluating predicates": capacity
is not pinned to a predicate for the lifetime of a query but flows to
wherever the bottleneck currently is. This module is the subsystem that
makes that true in this reproduction:

``DevicePool``
    Process-wide slot inventory per device group. A *slot* is the right to
    run one worker on that group. Groups may be bounded (``capacity``) or
    unbounded (the default — reproducing the per-predicate private pools
    that predate the arbiter). Slots remember their last holder so a
    handed-off lease can inherit the holder's simulated busy horizon
    (``SimClock.lease_handoff``), keeping the deterministic Fig. 7 / UC3
    timelines exact across reallocation.

``ResourceArbiter``
    Owns every ``WorkerContext`` (greedy allocation — contexts are cheap;
    activation stays conservative, per GACU §5.1) and leases device slots
    to predicates. Lifecycle of a lease:

      1. ``register(name, ...)`` — a ``LaminarRouter`` hands the arbiter a
         context factory; the arbiter pre-creates ``num_workers`` contexts.
      2. ``lease(name)`` — the router asks for one more worker. The
         configured ``ArbiterPolicy`` arbitrates between claimants: the
         default ``PressureRanked`` policy grants the slot to the claimant
         with the highest measured cost x queue-depth pressure (profiled
         statistics from the StatsBoard, never a-priori estimates — the
         GRACEFUL stance on UDF cost). A predicate with no leased worker
         bypasses ranking (floor guarantee: no starvation).
      3. ``release(name, worker)`` — the scale-DOWN path: the router
         retires a lease whose queue sat idle past the drain threshold;
         the slot returns to the pool, claimable by ANOTHER predicate's
         router (cross-predicate reallocation, counted in
         ``cross_pred_handoffs``).
      4. ``unregister(name)`` — executor shutdown; all held slots return.

    Counters (``counters()``) are surfaced through
    ``AQPExecutor.stats_snapshot()`` under the reserved ``"_arbiter"`` key.

    Multi-tenancy (QueryService, launch/serve.py): registrations — and
    therefore leases and slots — carry a QUERY identity (``register(...,
    query=...)``; ``Slot.last_query``), and each query may carry an
    URGENCY weight (``note_query_admitted``) folded into ``PressureRanked``
    arbitration so a higher-priority or deadline-pressed query wins
    contended slots at equal measured pressure. Admission and completion
    trigger a PREEMPTION-FREE ``rebalance()``: standing wants from
    predicates that no longer exert pressure are cleared so freed capacity
    flows to live claimants on their next ask — held leases are never
    revoked (routers retire their own leases via the drain path).

Thread-safety / lock order: router lock -> arbiter lock -> pool lock.
Pressure evaluation inside the arbiter deliberately reads only leaf-locked
structures (worker queues, PredicateStats) — never a router lock — so a
claimant's lease() can never deadlock against another router's retire path.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional

from repro.core.policies import ArbiterPolicy, PressureRanked
from repro.core.stats import StatsBoard

# Scale-down drain threshold (seconds of queue idleness before a worker
# lease retires). Generous by default so short-lived runs behave exactly
# like the pre-arbiter private pools; contended-pool deployments pass
# something much smaller (the UC2-realloc benchmark uses 0.05s).
DRAIN_THRESHOLD_S = 2.0


@dataclass
class Slot:
    """One unit of device-group capacity, handed out by the DevicePool."""

    group: str
    index: int
    last_holder: Optional[str] = None   # wid of the previous lease holder
    last_pred: Optional[str] = None     # predicate that last held the slot
    last_query: Optional[str] = None    # query identity of the last lease
    sim_horizon: float = 0.0            # SimClock busy horizon at release


class DevicePool:
    """Slot inventory per device group (process-wide when shared).

    ``capacity`` maps device-group name -> slot count; groups not listed
    fall back to ``default_capacity`` (``None`` = unbounded, the
    pre-arbiter behavior). Released slots are reissued LIFO so a re-leased
    slot is the most recently drained one — the holder whose simulated
    horizon is most likely still warm."""

    def __init__(self, capacity: Optional[Mapping[str, int]] = None,
                 default_capacity: Optional[int] = None):
        self._capacity = dict(capacity or {})
        self._default = default_capacity
        self._free: Dict[str, List[Slot]] = {}
        self._created: Dict[str, int] = {}
        self._lock = threading.Lock()

    def capacity_of(self, group: str) -> Optional[int]:
        return self._capacity.get(group, self._default)

    def in_use(self, group: str) -> int:
        with self._lock:
            return self._created.get(group, 0) - len(self._free.get(group, ()))

    def try_acquire(self, group: str) -> Optional[Slot]:
        with self._lock:
            free = self._free.get(group)
            if free:
                return free.pop()
            cap = self._capacity.get(group, self._default)
            n = self._created.get(group, 0)
            if cap is not None and n >= cap:
                return None
            self._created[group] = n + 1
            return Slot(group=group, index=n)

    def release(self, slot: Slot) -> None:
        with self._lock:
            self._free.setdefault(slot.group, []).append(slot)


class ResourceArbiter:
    """Owns all worker contexts; leases device slots to predicates."""

    def __init__(self, pool: Optional[DevicePool] = None,
                 policy: Optional[ArbiterPolicy] = None):
        self.pool = pool or DevicePool()
        self.policy = policy or PressureRanked()
        self._lock = threading.RLock()
        self._contexts: Dict[str, List] = {}
        self._leased: Dict[str, List] = {}
        self._slot_of: Dict[str, Slot] = {}      # wid -> held slot
        self._stats: Dict[str, StatsBoard] = {}
        self._clock: Dict[str, object] = {}
        self._wants: Dict[str, bool] = {}        # denied claimants (live ask)
        self._query: Dict[str, Optional[str]] = {}   # name -> query identity
        self._urgency: Dict[str, float] = {}     # query -> arbitration weight
        # reallocation counters (exposed via AQPExecutor.stats_snapshot)
        self.leases = 0
        self.releases = 0
        self.denials = 0
        self.cross_pred_handoffs = 0
        self.cross_query_handoffs = 0
        self.rebalances = 0

    # --------------------------- registration --------------------------- #
    def register(self, name: str, *, num_workers: int,
                 factory: Callable[[int], object],
                 stats: Optional[StatsBoard] = None,
                 clock: Optional[object] = None,
                 query: Optional[str] = None) -> List:
        """Greedy allocation: pre-create and return all contexts for
        ``name``.

        ``factory(i)`` builds the i-th context (the router closes over its
        queues/callbacks); the arbiter owns the list while registered and
        ALSO returns it so the registrant can keep its own reference — a
        long-lived shared arbiter drops the list on ``unregister`` rather
        than accumulating dead executors' worker graphs. A name may
        re-register only after ``unregister`` (sequential executors can
        reuse a shared arbiter); a currently-registered name is rejected
        outright — silently replacing another executor's contexts would
        cross-wire their pipelines."""
        with self._lock:
            if name in self._contexts:
                raise ValueError(
                    f"predicate {name!r} is already registered with this"
                    " arbiter (executors sharing an arbiter need distinct"
                    " predicate names; share only the DevicePool otherwise)"
                )
            ctxs = [factory(i) for i in range(num_workers)]
            self._contexts[name] = ctxs
            self._leased[name] = []
            if stats is not None:
                self._stats[name] = stats
            if clock is not None:
                self._clock[name] = clock
            self._wants[name] = False
            self._query[name] = query
            return ctxs

    def unregister(self, name: str) -> None:
        """Return every slot held by ``name`` and drop the registration
        (contexts included — the registrant holds its own reference)."""
        with self._lock:
            for w in list(self._leased.get(name, ())):
                self._release_locked(name, w)
            self._contexts.pop(name, None)
            self._leased.pop(name, None)
            self._wants.pop(name, None)
            self._stats.pop(name, None)
            self._clock.pop(name, None)
            self._query.pop(name, None)

    # ----------------------------- inventory ---------------------------- #
    def contexts(self, name: str) -> List:
        with self._lock:
            return list(self._contexts.get(name, ()))

    def leased(self, name: str) -> List:
        with self._lock:
            return list(self._leased.get(name, ()))

    @property
    def scale_down_enabled(self) -> bool:
        return self.policy.scale_down

    # ----------------------------- pressure ----------------------------- #
    def pressure_of(self, name: str) -> float:
        """Measured cost x queue-depth pressure of a claimant.

        Reads only leaf-locked state (worker input queues + the predicate's
        StatsBoard entry) — safe to evaluate under the arbiter lock from
        any thread."""
        with self._lock:
            leased = list(self._leased.get(name, ()))
            board = self._stats.get(name)
        depth = sum(len(w.queue) for w in leased)
        if board is None:
            return float(depth)
        return board[name].pressure(depth)

    # ------------------------------ leasing ------------------------------ #
    def lease(self, name: str):
        """Grant one worker lease to ``name``, or None (ceiling/denied).

        Floor guarantee: a claimant holding zero leases skips policy
        arbitration — it only needs a physically free slot — so a drained
        predicate can never be starved out of its last worker by a
        high-pressure rival."""
        with self._lock:
            ctxs = self._contexts.get(name)
            if ctxs is None:
                return None  # unregistered (e.g. a stray post-shutdown ask)
            held = self._leased[name]
            held_ids = {id(w) for w in held}
            candidates = [w for w in ctxs if id(w) not in held_ids]
            if not candidates:
                return None  # at this predicate's own ceiling
            if held:  # non-floor request: arbitrate between claimants
                pressures = {n: self.pressure_of(n) for n in self._contexts}
                # only rivals that could USE one of the requested groups
                # count: a standing claim on an exhausted 'gpu' group must
                # not block this predicate's free 'cpu' capacity
                groups = {w.device_group for w in candidates}
                wants = {
                    n: (w and bool(self._groups_locked(n) & groups))
                    for n, w in self._wants.items()
                }
                held_counts = {n: len(l) for n, l in self._leased.items()}
                urgency = {
                    n: self._urgency.get(self._query.get(n), 1.0)
                    for n in self._contexts
                } if self._urgency else None
                if not self.policy.grant(name, pressures=pressures,
                                         wants=wants, held=held_counts,
                                         urgency=urgency):
                    self._deny_locked(name)
                    return None
            for w in candidates:  # index order: deterministic activation
                slot = self.pool.try_acquire(w.device_group)
                if slot is None:
                    continue
                self._bind_locked(name, w, slot)
                return w
            self._deny_locked(name)
            return None

    def _groups_locked(self, name: str) -> set:
        return {w.device_group for w in self._contexts.get(name, ())}

    def _deny_locked(self, name: str) -> None:
        # count standing claims, not retry polls: routers re-ask a denied
        # lease every submit iteration, which would inflate the counter
        if not self._wants.get(name, False):
            self.denials += 1
        self._wants[name] = True

    def release(self, name: str, worker) -> None:
        with self._lock:
            self._release_locked(name, worker)

    # ----------------------------- internals ----------------------------- #
    def _bind_locked(self, name: str, w, slot: Slot) -> None:
        if slot.last_pred is not None and slot.last_pred != name:
            self.cross_pred_handoffs += 1
        query = self._query.get(name)
        if slot.last_query is not None and slot.last_query != query:
            self.cross_query_handoffs += 1
        slot.last_query = query
        clock = self._clock.get(name)
        if getattr(clock, "simulated", False) and slot.sim_horizon > 0.0:
            # the new lease inherits the physical slot's virtual horizon
            # (recorded at release), keeping deterministic timelines exact
            # across handoff — including across executors with separate
            # SimClocks that share only the DevicePool
            clock.seed_horizon(w.wid, slot.sim_horizon)
        slot.last_holder = w.wid
        slot.last_pred = name
        self._slot_of[w.wid] = slot
        self._leased[name].append(w)
        self._wants[name] = False
        self.leases += 1

    def _release_locked(self, name: str, w) -> None:
        held = self._leased.get(name, [])
        if w not in held:
            return
        held.remove(w)
        slot = self._slot_of.pop(w.wid, None)
        if slot is not None:
            clock = self._clock.get(name)
            if getattr(clock, "simulated", False):
                # detach the worker's horizon: the outstanding virtual
                # work travels with the SLOT from here on
                slot.sim_horizon = clock.release_horizon(w.wid)
            else:
                slot.sim_horizon = 0.0
            slot.last_holder = w.wid
            slot.last_pred = name
            slot.last_query = self._query.get(name)
            self.pool.release(slot)
        self._wants[name] = False
        self.releases += 1

    # --------------------------- multi-tenancy --------------------------- #
    def note_query_admitted(self, query: str, urgency: float = 1.0) -> None:
        """A query entered the service: record its arbitration urgency.

        ``urgency`` (see ``policies.urgency_weight``) multiplies the
        measured pressure of every predicate registered under ``query``
        during ``PressureRanked`` arbitration. Admission triggers a
        preemption-free ``rebalance()`` so standing wants from finished
        tenants don't shadow the newcomer's first asks."""
        with self._lock:
            self._urgency[query] = float(urgency)
        self.rebalance()

    def note_query_finished(self, query: str) -> None:
        """A query left the service: drop its urgency and rebalance."""
        with self._lock:
            self._urgency.pop(query, None)
        self.rebalance()

    def rebalance(self) -> None:
        """Preemption-free rebalance on query admit/finish.

        Clears standing wants from claimants that no longer exert pressure
        (their queues drained or they unregistered) so freed capacity flows
        to live claimants on their next ask. Held leases are NEVER revoked
        — routers retire their own leases via the drain path."""
        with self._lock:
            stale = [
                n for n, wanting in self._wants.items()
                if wanting and (n not in self._contexts
                                or self.pressure_of(n) <= 0.0)
            ]
            for n in stale:
                self._wants[n] = False
            self.rebalances += 1

    # ------------------------------ metrics ------------------------------ #
    def counters(self) -> Dict[str, object]:
        with self._lock:
            return {
                "leases": self.leases,
                "releases": self.releases,
                "denials": self.denials,
                "cross_pred_handoffs": self.cross_pred_handoffs,
                "cross_query_handoffs": self.cross_query_handoffs,
                "rebalances": self.rebalances,
                "policy": self.policy.name,
            }
