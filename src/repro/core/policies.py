"""Routing policies (§4.1, §4.3, §5.3).

EDDY policies rank the unvisited predicates for a batch; the routing shard
sends the batch to the first. All estimates come from run-time stats
(StatsBoard) — never a-priori. On a sharded board (N-shard eddy core),
``stats[name]`` yields a MERGED view folding every shard's write stripe,
so each shard ranks on global statistics while recording stays
uncontended; policies are stateless sorts (or keep only GIL-atomic
counters), so one policy instance is safely shared by all shards.

  * CostDriven       — Hydro's contribution: rank by measured cost/row.
                       Optimal when predicates run CONCURRENTLY (different
                       resources): the cheap predicate drains the pipeline
                       and the expensive one overlaps (paper Fig. 4:
                       14 vs 20 time units).
  * ScoreDriven      — classic cost/(1-selectivity) [Hellerstein '94].
  * SelectivityDriven— rank by selectivity only (ablation).
  * ReuseAware       — CostDriven with per-BATCH cache-hit discounting:
                       est = (1 - hit_rate(batch)) * cost  (§4.3).
  * HydroPolicy      — cost-driven when the batch's unvisited predicates
                       occupy pairwise-disjoint resources (concurrent),
                       else falls back to score-driven, per §4.1.

LAMINAR policies pick a worker for a batch:
  * RoundRobin        — paper default.
  * DataAware         — least outstanding PROXY load (input size), assigned
                        proactively at enqueue (§5.3).
  * DeviceAlternating — alternate device groups on consecutive batches
                        (the paper's GPU-aware routing, §5.1 scaling out).

ARBITER policies decide which predicate a contended device slot goes to
(§5.2 dynamic resource allocation; see core/resources.py):
  * PressureRanked    — default: highest measured cost x queue-depth wins;
                        deadline/priority-aware when claimants carry an
                        URGENCY weight (multi-tenant QueryService: each
                        query's priority x deadline proximity scales its
                        predicates' pressure in the comparison).
  * StaticPartition   — ablation: fixed per-predicate quota, no scale-down.
"""
from __future__ import annotations

import itertools
from typing import List, Optional

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate


# Rank-estimate resolution (Adaptive Cost Model line of work): run-time
# statistics are noisy estimators, so two predicates whose true statistics
# are EQUAL will report values that differ by estimator noise (the lottery
# selectivity estimator drifts by ~1/tickets per batch). Ranking on the raw
# floats makes the predicate order flip nondeterministically mid-run at
# degenerate (tied) statistics. Policies therefore quantize selectivity to
# this resolution inside their sort keys — well above the noise floor, well
# below any meaningful selectivity difference — and break the resulting
# ties deterministically (cost, then name). Point estimates returned by
# ``PredicateStats`` stay exact; only rank keys quantize.
SEL_RESOLUTION = 1.0 / 64.0


def _sel_key(sel: float, resolution: float = SEL_RESOLUTION) -> float:
    """Selectivity as a rank key: quantized so noise-level differences tie."""
    return round(sel / resolution) * resolution


def _fault_penalty(stats, name: str) -> float:
    """Failure-aware rank multiplier (core/faults.py): the error-rate EMA
    of a flaky predicate inflates its cost/score key so healthy siblings
    run first — a soft deferral; outright removal from routing is the
    quarantine skip in the eddy shard, not a policy concern.  Exactly 1.0
    (so ``key * 1.0 == key`` bit-exact) when no ledger is attached or the
    predicate has never failed; SelectivityDriven deliberately ignores it
    (pure-selectivity ablation)."""
    ledger = getattr(stats, "faults", None)
    return 1.0 if ledger is None else ledger.rank_penalty(name)


class EddyPolicy:
    name = "base"

    def rank(self, batch: RoutingBatch, preds: List[Predicate],
             stats: StatsBoard, cache: Optional[ReuseCache]) -> List[Predicate]:
        raise NotImplementedError


class CostDriven(EddyPolicy):
    name = "cost"

    def est_cost(self, batch, p, stats, cache) -> float:
        return stats[p.name].cost()

    def rank(self, batch, preds, stats, cache):
        # deterministic tie-break: equal-cost predicates order by
        # (quantized) selectivity — drop more rows first — then by name.
        return sorted(preds, key=lambda p: (
            self.est_cost(batch, p, stats, cache) * _fault_penalty(stats, p.name),
            _sel_key(stats[p.name].selectivity()),
            p.name,
        ))


class ReuseAware(CostDriven):
    name = "reuse-aware"

    def est_cost(self, batch, p, stats, cache) -> float:
        cost = stats[p.name].cost()
        if cache is None or not p.cacheable:
            return cost
        # pass the UDF's input columns so a LAYERED cache can fold
        # content-hash hits (same payload under fresh row ids) into the
        # paper's (1 - hit_rate) x cost estimate; id-keyed caches ignore it
        data = {c: batch.data[c] for c in p.udf.columns if c in batch.data}
        hit = cache.hit_rate(p.udf.name, batch.row_ids, data=data or None)
        return (1.0 - hit) * cost


class ScoreDriven(EddyPolicy):
    name = "score"

    def rank(self, batch, preds, stats, cache):
        return sorted(preds, key=lambda p: (
            stats[p.name].score(resolution=SEL_RESOLUTION)
            * _fault_penalty(stats, p.name),
            stats[p.name].cost(),
            p.name,
        ))


class SelectivityDriven(EddyPolicy):
    name = "selectivity"

    def rank(self, batch, preds, stats, cache):
        # quantized selectivity first; at a tie the cheaper predicate runs
        # first (the only well-defined order at degenerate statistics).
        return sorted(preds, key=lambda p: (
            _sel_key(stats[p.name].selectivity()),
            stats[p.name].cost(),
            p.name,
        ))


class ContentBased(EddyPolicy):
    """Content-based routing [Bizarro et al. 2005, the paper's §2.2].

    Per-batch predicate ordering from CONTENT-bucket-specific selectivities
    (lottery counters keyed by ``bucket_fn(batch)``). The original
    tuple-granularity overhead objection dissolves at Hydro's routing-batch
    granularity: one bucket lookup per ~10-row batch. Falls back to global
    estimates until a bucket accumulates enough tickets."""

    name = "content"

    def __init__(self, bucket_fn):
        self.bucket_fn = bucket_fn

    def rank(self, batch, preds, stats, cache):
        if stats.bucket_fn is None:
            # wire the eval-side recording; benign if shards race here
            # (every shard writes the same function)
            stats.bucket_fn = self.bucket_fn
        b = stats.bucket_of(batch)
        return sorted(preds, key=lambda p: (
            stats[p.name].score(bucket=b, resolution=SEL_RESOLUTION)
            * _fault_penalty(stats, p.name),
            stats[p.name].cost(),
            p.name,
        ))


class HydroPolicy(EddyPolicy):
    """Cost-driven under concurrency, score-driven otherwise (§4.1)."""

    name = "hydro"

    def __init__(self):
        self._cost = CostDriven()
        self._score = ScoreDriven()

    def rank(self, batch, preds, stats, cache):
        resources = [p.resource for p in preds]
        concurrent = len(set(resources)) == len(resources)
        inner = self._cost if concurrent else self._score
        return inner.rank(batch, preds, stats, cache)


# --------------------------------------------------------------------------- #
# Laminar policies                                                             #
# --------------------------------------------------------------------------- #
class LaminarPolicy:
    name = "base"

    def choose(self, workers, batch: RoutingBatch, stats: StatsBoard):
        raise NotImplementedError


class RoundRobin(LaminarPolicy):
    name = "round-robin"

    def __init__(self):
        self._counter = itertools.count()

    def choose(self, workers, batch, stats):
        return workers[next(self._counter) % len(workers)]


class DataAware(LaminarPolicy):
    """Least outstanding proxy load; load added proactively at enqueue.

    Under the simulated clock the authoritative outstanding-work signal is
    the worker's VIRTUAL busy horizon (completed-but-virtually-queued work
    drains at sim time, not wall time); the proactive proxy load breaks
    ties for batches submitted but not yet evaluated."""

    name = "data-aware"

    def choose(self, workers, batch, stats):
        from repro.core.simclock import SimClock

        clock = getattr(workers[0], "clock", None)
        if isinstance(clock, SimClock):
            # expected completion: virtual horizon (evaluated-queued work)
            # + pending proxy load converted to seconds by the measured rate
            rate = stats.proxy_rate.get(0.0)

            def eta(w):
                start = max(clock.resource_busy_until(w.wid), batch.sim_ready)
                return start + stats.load_of(w.wid) * rate

            return min(workers, key=eta)
        return min(workers, key=lambda w: stats.load_of(w.wid))


class DeviceAlternating(LaminarPolicy):
    """Alternate across device groups for consecutive batches (§5.1)."""

    name = "device-alternating"

    def __init__(self):
        self._counter = itertools.count()
        self._inner: dict = {}

    def choose(self, workers, batch, stats):
        devices = sorted({w.device_group for w in workers})
        dev = devices[next(self._counter) % len(devices)]
        group = [w for w in workers if w.device_group == dev]
        inner = self._inner.setdefault(dev, itertools.count())
        return group[next(inner) % len(group)]


class StickyDevice(LaminarPolicy):
    """Route RUNS of consecutive batches to the same device group — the
    paper's non-GPU-aware baseline (continuous data sequences land on one
    accelerator), used as the UC3 'w/o alternating' ablation."""

    name = "sticky-device"

    def __init__(self, run_length: int = 16):
        self.run_length = run_length
        self._n = 0
        self._inner: dict = {}

    def choose(self, workers, batch, stats):
        devices = sorted({w.device_group for w in workers})
        dev = devices[(self._n // self.run_length) % len(devices)]
        self._n += 1
        group = [w for w in workers if w.device_group == dev]
        inner = self._inner.setdefault(dev, itertools.count())
        return group[next(inner) % len(group)]


# --------------------------------------------------------------------------- #
# Arbiter policies (§5.2 dynamic resource allocation)                          #
# --------------------------------------------------------------------------- #
def urgency_weight(priority: float = 1.0, deadline: Optional[float] = None,
                   now: float = 0.0) -> float:
    """Deadline/priority urgency multiplier for arbitration pressure.

    ``priority`` scales linearly (a priority-2 query's predicates weigh
    twice a priority-1 rival's at equal measured pressure). A ``deadline``
    (absolute, same clock as ``now``) adds proximity urgency that grows as
    the deadline nears: with ``t = deadline - now`` seconds remaining the
    weight is ``priority * (1 + 1 / max(t, 0.1))`` — an already-missed or
    imminent deadline saturates at ``priority * 11`` rather than diverging,
    so one late query cannot starve the fleet forever."""
    w = max(0.0, float(priority))
    if deadline is not None:
        w *= 1.0 + 1.0 / max(float(deadline) - float(now), 0.1)
    return w


class ArbiterPolicy:
    """Arbitrates device-slot leases between predicate claimants.

    ``grant`` is consulted by ``ResourceArbiter.lease`` for every non-floor
    request (a claimant's FIRST lease always bypasses arbitration — the
    no-starvation floor). ``scale_down`` gates the drain-threshold retire
    path: a policy that forbids it reproduces pools that only grow."""

    name = "base"
    scale_down = True

    def grant(self, requester: str, *, pressures, wants, held,
              urgency=None) -> bool:
        """May ``requester`` take a free slot right now?

        pressures: claimant -> measured cost x queue-depth pressure
        wants:     claimant -> was recently denied (a live, standing claim)
        held:      claimant -> leases currently held
        urgency:   claimant -> deadline/priority weight (``urgency_weight``)
                   or None — absent claimants weigh 1.0, so a single-query
                   executor arbitrates exactly as before the QueryService
        """
        raise NotImplementedError


class PressureRanked(ArbiterPolicy):
    """Default: the slot goes to the highest-pressure standing claimant.

    Pressure is profiled cost/row x queue depth from the StatsBoard (§3.3:
    collected DURING execution — the GRACEFUL argument for profiled over
    estimated UDF cost). A requester outranked by a rival with a standing
    denied claim steps aside; rivals whose pressure has since drained to or
    below the requester's no longer block (stale wants are harmless because
    pressures are always read live).

    Deadline/priority awareness (multi-tenant QueryService): each
    claimant's pressure is scaled by its query's urgency weight before the
    comparison, so a higher-priority or deadline-pressed query wins
    contended slots at equal measured pressure. With no urgency map (the
    single-query executor) every weight is 1.0 — bit-identical to the
    pre-service arbitration."""

    name = "pressure"

    def grant(self, requester, *, pressures, wants, held, urgency=None):
        rivals = [n for n, w in wants.items() if w and n != requester]
        if not rivals:
            return True
        u = urgency or {}
        mine = pressures.get(requester, 0.0) * u.get(requester, 1.0)
        return all(
            pressures.get(n, 0.0) * u.get(n, 1.0) <= mine for n in rivals
        )


class StaticPartition(ArbiterPolicy):
    """Ablation: the pre-arbiter behavior — a fixed per-predicate quota,
    no scale-down, no cross-predicate reallocation. ``quota=None`` means
    each predicate is limited only by its own ``max_workers`` ceiling
    (exactly the old private pools)."""

    name = "static"
    scale_down = False

    def __init__(self, quota: Optional[int] = None):
        self.quota = quota

    def grant(self, requester, *, pressures, wants, held, urgency=None):
        if self.quota is None:
            return True
        return held.get(requester, 0) < self.quota


EDDY_POLICIES = {
    p.name: p for p in (CostDriven, ScoreDriven, SelectivityDriven, ReuseAware, HydroPolicy)
}
EDDY_POLICIES_EXT = dict(EDDY_POLICIES, content=ContentBased)
LAMINAR_POLICIES = {
    p.name: p for p in (RoundRobin, DataAware, DeviceAlternating, StickyDevice)
}
ARBITER_POLICIES = {p.name: p for p in (PressureRanked, StaticPartition)}
