"""Eddy pull + router (§3.2, §4.1).

EDDY PULL drains the child executor into the central queue, honoring the
lambda watermark. EDDY ROUTER orchestrates: completed batches (all
predicates visited, or emptied by eager materialization) go to the output
queue; unfinished batches go to the Laminar router of the predicate chosen
by the routing policy.

WARMUP (§4.1): until every predicate has at least one measurement, the
first batches are fanned out round-robin so all predicates get measured in
parallel; other batches are DELAYED via the circular flow — popped from the
head of the central queue and reinserted at the tail — so no batch is
routed in a possibly-suboptimal order before statistics exist.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.laminar import LaminarRouter
from repro.core.policies import EddyPolicy
from repro.core.queues import BoundedQueue, CentralQueue, ClosedError
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch

# Circular-flow back-off during warmup (§4.1): a batch that cannot help
# warmup is reinserted at the tail, and the router yields briefly so the
# head->tail cycle doesn't hot-spin a 1-core host while the warmup
# evaluations run on the worker threads.
WARMUP_CIRCULATION_SLEEP_S = 0.0005


class EddyPull(threading.Thread):
    """Pulls batches from the child iterator into the central queue."""

    def __init__(self, source: Iterable[RoutingBatch], central: CentralQueue,
                 *, launch_token=None):
        super().__init__(daemon=True, name="eddy-pull")
        self.source = source
        self.central = central
        self.injected = 0
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.launch_token = launch_token

    def run(self) -> None:
        if self.launch_token is not None:
            kernel_launch.set_launch_context(self.launch_token)
        try:
            for batch in self.source:
                self.injected += 1
                while not self.central.put_pull(batch, timeout=0.2):
                    pass  # below-watermark wait (deadlock prevention, §3.3)
        except ClosedError:
            pass
        except BaseException as e:  # surfaced by the executor
            self.error = e
        finally:
            self.done.set()


class EddyRouter(threading.Thread):
    """The orchestration loop: completion, warmup, policy routing."""

    def __init__(
        self,
        preds: List[Predicate],
        central: CentralQueue,
        output: BoundedQueue,
        laminars: Dict[str, LaminarRouter],
        stats: StatsBoard,
        policy: EddyPolicy,
        pull: EddyPull,
        *,
        cache: Optional[ReuseCache] = None,
        warmup: bool = True,
        launch_token=None,
    ):
        super().__init__(daemon=True, name="eddy-router")
        self.preds = preds
        self.central = central
        self.output = output
        self.laminars = laminars
        self.stats = stats
        self.policy = policy
        self.pull = pull
        self.cache = cache
        self.warmup_enabled = warmup and len(preds) > 1
        self.completed = 0
        self.error: Optional[BaseException] = None
        self._warmup_dispatched: set = set()
        self.circulations = 0
        self.launch_token = launch_token

    # ------------------------------------------------------------------ #
    def _in_flight(self) -> int:
        return self.pull.injected - self.completed

    def _route(self, batch: RoutingBatch) -> None:
        remaining = batch.unvisited(self.preds)
        in_warmup = self.warmup_enabled and not self.stats.all_measured()

        if in_warmup:
            # "just enough batches": one warmup batch per unmeasured predicate
            candidates = [
                p for p in remaining
                if not self.stats[p.name].measured
                and p.name not in self._warmup_dispatched
            ]
            if candidates:
                target = candidates[0]
                self._warmup_dispatched.add(target.name)
                self.laminars[target.name].submit(batch)
                return
            # can't help warmup: circular delay (head -> tail, §4.1)
            self.circulations += 1
            self.central.put_worker(batch)
            time.sleep(WARMUP_CIRCULATION_SLEEP_S)
            return

        ranked = self.policy.rank(batch, remaining, self.stats, self.cache)
        self.laminars[ranked[0].name].submit(batch)

    def run(self) -> None:
        if self.launch_token is not None:
            # warm_fn probes run on this thread (worker activation happens
            # inside submit): tag it so those launches attribute here too
            kernel_launch.set_launch_context(self.launch_token)
        try:
            while True:
                if (
                    self.pull.done.is_set()
                    and self._in_flight() == 0
                ):
                    break
                try:
                    batch = self.central.get(timeout=0.1)
                except TimeoutError:
                    continue
                except ClosedError:
                    break
                if batch.done(self.preds):
                    self.completed += 1
                    if not batch.empty:
                        self.output.put(batch)
                    continue
                self._route(batch)
        except BaseException as e:
            self.error = e
        finally:
            self.output.close()
