"""Sharded eddy routing core (§3.2, §4.1): pull -> partition -> shard loop
-> steal -> merged stats.

EDDY PULL drains the child executor into the central queue, honoring the
lambda watermark (one blocking wait per batch; ``close()`` wakes it).

The routing core is an EDDY SHARD SET: N shards, each owning one stripe of
the central queue and running the full completion/warmup/policy loop.

  data flow:   pull --round-robin--> stripe_i --> shard_i loop
               shard_i: completed?  -> output stripe_i
                        warmup?     -> fan-out / circulate (tail reinsert)
                        else        -> policy.rank on MERGED stats -> Laminar
               worker reinsert      -> home stripe (bid % active shards)
               stripe_i drained?    -> shard_i STEALS from the longest
                                       sibling stripe (consumer-side only,
                                       so the lambda-watermark deadlock
                                       invariant is untouched)

Statistics are lock-sharded (see core/stats.py): workers record into
thread-affine stripes; every shard's policy ranks on a merged snapshot, so
per-shard writes are uncontended and reads see the global picture.

TERMINATION: a shared in-flight tracker (incremented by the pull before a
batch enters the queue, decremented by the shard that completes it)
replaces the old unsynchronized ``pull.injected - completed`` read; a shard
exits when the pull is done AND the tracker reads zero, and the LAST shard
out closes the output queue — the termination barrier.  Micro-batch
coalescing preserves the invariant by construction: a worker that fuses k
queued batches into one launch splits the result back into exactly k
output batches, one per original ``bid`` (core/batch.split_back), so every
``started()`` batch still produces exactly one completion — the tracker
never needs to know fusing happened.

WARMUP (§4.1): until every predicate has at least one measurement, the
first batches are fanned out round-robin so all predicates get measured in
parallel (the dispatched set is shared across shards under a lock); other
batches are DELAYED via the circular flow — popped from the head of their
stripe and reinserted at the TAIL via ``put_worker`` — so no batch is
routed in a possibly-suboptimal order before statistics exist.

AUTO-SCALING: constructed with ``shards < max_shards`` the set starts one
shard and grows to ``max_shards`` once observed routing throughput crosses
``auto_threshold`` batches/s (the regime where routing, not UDF eval, is
the ceiling). Deterministic (SimClock) executors never auto-scale.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Iterable, List, Optional

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.laminar import LaminarRouter
from repro.core.policies import EddyPolicy
from repro.core.queues import CentralQueue, ClosedError
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch

# Circular-flow back-off during warmup (§4.1): a batch that cannot help
# warmup is reinserted at the tail, and the shard yields briefly so the
# head->tail cycle doesn't hot-spin a 1-core host while the warmup
# evaluations run on the worker threads.
WARMUP_CIRCULATION_SLEEP_S = 0.0005

# Shard-loop poll interval for the termination check while the stripe is
# empty (a shard blocked here wakes on its stripe's condition variable for
# new work; the timeout only bounds how fast it notices global completion).
SHARD_GET_TIMEOUT_S = 0.05

# Auto-scaling defaults: grow to SHARD_AUTO_MAX shards once at least
# SHARD_AUTO_MIN_COMPLETED batches completed at a measured routing rate
# above SHARD_AUTO_THRESHOLD_BPS batches/s — the issue's "<5 ms/batch"
# regime where the single-threaded router, not UDF eval, caps utilization.
SHARD_AUTO_MAX = 4
SHARD_AUTO_THRESHOLD_BPS = 200.0
SHARD_AUTO_MIN_COMPLETED = 64


class InFlightTracker:
    """Atomic in-flight batch count shared by the pull and every shard.

    The old single-threaded router computed ``pull.injected - completed``
    from two unsynchronized counters — benign with one router thread,
    a missed-termination/early-exit hazard with N shards. The pull calls
    ``started()`` BEFORE the batch enters the central queue and shards call
    ``finished()`` when a batch completes, so ``value() == 0`` together
    with ``pull.done`` is a safe global-quiescence condition.  Fused
    (coalesced) launches split back into one output per original batch, so
    the per-batch accounting holds unchanged with coalescing enabled."""

    def __init__(self) -> None:
        self._n = 0
        self._lock = threading.Lock()

    def started(self) -> None:
        with self._lock:
            self._n += 1

    def finished(self) -> None:
        with self._lock:
            self._n -= 1

    def value(self) -> int:
        with self._lock:
            return self._n


class EddyPull(threading.Thread):
    """Pulls batches from the child iterator into the central queue."""

    def __init__(self, source: Iterable[RoutingBatch], central: CentralQueue,
                 *, launch_token=None,
                 tracker: Optional[InFlightTracker] = None):
        super().__init__(daemon=True, name="eddy-pull")
        self.source = source
        self.central = central
        self.injected = 0
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.launch_token = launch_token
        self.tracker = tracker or InFlightTracker()

    def run(self) -> None:
        if self.launch_token is not None:
            kernel_launch.set_launch_context(self.launch_token)
        try:
            for batch in self.source:
                # count BEFORE the queue insert: a batch is in flight from
                # the moment it leaves the source iterator
                self.tracker.started()
                self.injected += 1
                try:
                    # single blocking below-watermark wait; close() wakes it
                    # with ClosedError (no 5 Hz busy-retry loop)
                    self.central.put_pull(batch)
                except BaseException:
                    self.tracker.finished()  # batch never entered the queue
                    raise
        except ClosedError:
            pass
        except BaseException as e:  # surfaced by the executor
            self.error = e
        finally:
            self.done.set()


class EddyShard(threading.Thread):
    """One routing shard: the full completion/warmup/policy loop over its
    own central-queue stripe, stealing from siblings when it drains."""

    def __init__(self, idx: int, core: "EddyShardSet"):
        super().__init__(daemon=True, name=f"eddy-shard-{idx}")
        self.idx = idx
        self.core = core
        self.completed = 0
        self.circulations = 0
        self.error: Optional[BaseException] = None

    def _route(self, batch: RoutingBatch) -> None:
        """Route one non-done batch.  Any failure to hand the batch onward
        (a closed worker queue, a starvation deadline, a policy error)
        decrements the in-flight tracker before re-raising — the batch is
        lost, but the termination barrier stays exact, so sibling shards
        and the executor observe completion instead of hanging forever on
        a count that can never reach zero."""
        try:
            self._route_inner(batch)
        except BaseException:
            self.core.tracker.finished()
            raise

    def _route_inner(self, batch: RoutingBatch) -> None:
        core = self.core
        remaining = batch.unvisited(core.preds)
        ledger = core.faults
        quarantined = ()
        if ledger is not None and ledger.has_quarantined:
            quarantined = ledger.quarantined_names()
            skipped = [p for p in remaining if p.name in quarantined]
            if skipped:
                # failure-aware skip: a fully-quarantined predicate gets
                # the conservative pass-through verdict at ROUTING time —
                # the decision is logged per predicate in the ledger.
                # Exception: an armed recovery probe
                # (FaultConfig.probe_after_skips) claims ONE batch and
                # routes it AT the quarantined predicate instead — probe
                # success un-quarantines it (see faults.py).
                for p in skipped:
                    if ledger.take_probe_route(p.name):
                        self._submit(core.laminars[p.name], batch)
                        return
                    batch = batch.mark_passthrough(p.name)
                    ledger.note_skip(p.name)
                remaining = [p for p in remaining
                             if p.name not in quarantined]
                if not remaining:
                    # completed by skips alone: reinsert; the next pop
                    # sees batch.done() and finishes it normally
                    core.central.put_worker(batch)
                    return
        warmup_exempt = quarantined
        if ledger is not None and ledger.dirty:
            # a predicate that has FAILED and never measured may never
            # produce a measurement; warmup dispatches one batch per
            # predicate exactly once, so gating all-measured on it would
            # circulate every other batch forever — exempt it from the
            # gate (normal ranking still routes batches at it until it
            # recovers or quarantines)
            warmup_exempt = set(quarantined) | set(ledger.failed_names())
        if core.warmup_enabled \
                and not core.stats.all_measured(exclude=warmup_exempt):
            target = core.claim_warmup(remaining)
            if target is not None:
                self._submit(core.laminars[target.name], batch)
                return
            # can't help warmup: circular delay (head -> TAIL, §4.1)
            self.circulations += 1
            core.central.put_worker(batch)
            time.sleep(WARMUP_CIRCULATION_SLEEP_S)
            return
        ranked = core.policy.rank(batch, remaining, core.stats, core.cache)
        self._submit(core.laminars[ranked[0].name], batch)

    @staticmethod
    def _submit(laminar, batch: RoutingBatch) -> None:
        """Hand a batch to a Laminar router, REFUSING the silent-drop
        path: ``submit`` contracts to return True or raise, but if a
        router implementation ever returns falsy without raising, the
        batch would vanish and wedge the termination barrier — turn that
        into a loud error (which ``_route`` converts into a tracker
        decrement + shard error)."""
        if not laminar.submit(batch):
            raise RuntimeError(
                f"laminar router for {laminar.pred.name!r} rejected batch "
                f"{batch.bid} without raising — batch would be lost"
            )

    def run(self) -> None:
        core = self.core
        if core.launch_token is not None:
            # warm_fn probes run on this thread (worker activation happens
            # inside submit): tag it so those launches attribute here too
            kernel_launch.set_launch_context(core.launch_token)
        try:
            while True:
                if core.pull.done.is_set() and core.tracker.value() == 0:
                    break
                try:
                    batch = core.central.get(
                        timeout=SHARD_GET_TIMEOUT_S, shard=self.idx
                    )
                except TimeoutError:
                    continue
                except ClosedError:
                    break
                if batch.done(core.preds):
                    self.completed += 1
                    core.tracker.finished()
                    if not batch.empty:
                        core.output.put(batch, shard=self.idx)
                    core.maybe_grow()
                    continue
                self._route(batch)
        except ClosedError:
            pass  # queue torn down mid-route: clean shutdown, not an error
        except BaseException as e:
            self.error = e
            # wake everything NOW: sibling shards get ClosedError instead
            # of polling out their timeouts, the pull stops injecting, and
            # the executor's output wait surfaces the error promptly
            core.abort()
        finally:
            core._shard_exited()


class EddyShardSet:
    """N routing shards over a sharded central queue with merged statistics.

    Replaces the single-threaded ``EddyRouter``. Shared state: the
    in-flight tracker (termination), the warmup-dispatch set, and the
    StatsBoard (whose per-shard write stripes merge on read). The last
    shard to exit closes the output queue."""

    def __init__(
        self,
        preds: List[Predicate],
        central: CentralQueue,
        output: CentralQueue,
        laminars: Dict[str, LaminarRouter],
        stats: StatsBoard,
        policy: EddyPolicy,
        pull: EddyPull,
        *,
        cache: Optional[ReuseCache] = None,
        warmup: bool = True,
        launch_token=None,
        shards: int = 1,
        max_shards: Optional[int] = None,
        auto_threshold: float = SHARD_AUTO_THRESHOLD_BPS,
        tracker: Optional[InFlightTracker] = None,
        faults=None,
    ):
        self.preds = preds
        # per-predicate FaultLedger (core/faults.py) or None: routing
        # skips fully-quarantined predicates with a logged pass-through
        self.faults = faults
        self.central = central
        self.output = output
        self.laminars = laminars
        self.stats = stats
        self.policy = policy
        self.pull = pull
        self.cache = cache
        self.warmup_enabled = warmup and len(preds) > 1
        self.launch_token = launch_token
        self.tracker = tracker or pull.tracker
        self.auto_threshold = auto_threshold
        self.initial_shards = max(1, shards)
        self.max_shards = max(self.initial_shards, max_shards or 0)
        self._shards = [EddyShard(i, self) for i in range(self.max_shards)]
        self._lock = threading.Lock()
        self._live = 0
        self._active = 0
        self._scaled = self.initial_shards >= self.max_shards
        self._warmup_dispatched: set = set()
        self._t0: Optional[float] = None
        self.grew_at: Optional[int] = None  # completed count at scale-up

    # ------------------------------------------------------------------ #
    def start(self) -> None:
        self._t0 = time.monotonic()
        self.central.set_active_shards(self.initial_shards)
        with self._lock:
            for s in self._shards[: self.initial_shards]:
                self._live += 1
                self._active += 1
                s.start()

    def maybe_grow(self) -> None:
        """Auto-scale: start the remaining shards once measured routing
        throughput crosses the threshold (one-shot, any shard may trip it)."""
        if self._scaled:
            return
        done = self.completed
        if done < SHARD_AUTO_MIN_COMPLETED:
            return
        elapsed = time.monotonic() - self._t0
        if elapsed <= 0 or done / elapsed < self.auto_threshold:
            return
        with self._lock:
            if self._scaled:
                return
            self._scaled = True
            self.grew_at = done
            for s in self._shards[self._active:]:
                self._live += 1
                self._active += 1
                s.start()
        self.central.set_active_shards(self.max_shards)

    def claim_warmup(self, remaining: List[Predicate]) -> Optional[Predicate]:
        """ "Just enough batches": one warmup batch per unmeasured predicate,
        the dispatched set shared across shards under one short lock."""
        with self._lock:
            for p in remaining:
                if (not self.stats[p.name].measured
                        and p.name not in self._warmup_dispatched):
                    self._warmup_dispatched.add(p.name)
                    return p
        return None

    def abort(self) -> None:
        """Error teardown: close both queues so every blocked thread (the
        pull's watermark wait, sibling shards' stripe waits, the
        executor's output wait) wakes with ClosedError immediately
        instead of discovering the failure by poll timeout."""
        self.central.close()
        self.output.close()

    def _shard_exited(self) -> None:
        with self._lock:
            self._live -= 1
            last = self._live == 0
        if last:  # termination barrier: only the last shard out closes
            self.output.close()

    # ------------------------------ metrics ---------------------------- #
    @property
    def shards_active(self) -> int:
        with self._lock:
            return self._active

    @property
    def completed(self) -> int:
        return sum(s.completed for s in self._shards)

    @property
    def circulations(self) -> int:
        return sum(s.circulations for s in self._shards)

    @property
    def steals(self) -> int:
        return self.central.steals

    @property
    def error(self) -> Optional[BaseException]:
        for s in self._shards:
            if s.error is not None:
                return s.error
        return None
