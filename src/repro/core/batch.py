"""RoutingBatch — the unit of data flowing through the AQP executor (§3.3).

Each batch carries a unique id (cheaper than hashing multi-dimensional
payloads, exactly as the paper argues), column data, per-row source ids (for
the reuse cache), and the set of predicates already evaluated. Eager
materialization: ``filter`` drops failing rows immediately so later
predicates see only survivors.

COALESCING CONTRACT (``concat`` / ``split_back``): a worker may fuse
several queued batches destined for the same predicate into ONE batch for
a single kernel launch (amortizing per-launch dispatch/trace/probe
overhead — §5.1's utilization argument applied to tiny batches).  The
contract is that fusing is invisible to routing semantics:

* ``concat`` stacks the batches column-wise (``np.concatenate``) and
  records per-batch segment boundaries — each ``BatchSegment`` keeps a
  reference to its ORIGINAL batch plus its ``[start, stop)`` row span in
  the fused payload, so ``(bid, visited, warmup, created_at, sim_ready)``
  survive exactly.
* Predicates are row-wise: evaluating the fused batch yields, row for
  row, the same outputs/mask each batch would have seen alone.
* ``split_back`` slices the fused row mask at the segment boundaries and
  applies each slice to the segment's ORIGINAL batch — so every output
  batch is bit-identical (bid, visited set, surviving row multiset,
  per-row data) to what the uncoalesced path would have produced.  Only
  ``sim_ready`` differs by design under SimClock: every segment inherits
  the single fused launch's finish time (one launch term + summed row
  terms, see core/simclock.py).

The fused batch itself is transient — it exists only between dequeue and
split, never enters a queue, and its fresh ``bid`` is never observed by
the in-flight tracker (which counts the per-``bid`` split outputs).
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

_next_id = itertools.count()
_id_lock = threading.Lock()


def _new_id() -> int:
    with _id_lock:
        return next(_next_id)


@dataclass(frozen=True)
class RoutingBatch:
    data: Dict[str, np.ndarray]          # column -> (rows, ...) arrays
    row_ids: np.ndarray                  # (rows,) stable source ids (cache keys)
    bid: int = field(default_factory=_new_id)
    visited: FrozenSet[str] = frozenset()
    warmup: bool = False
    created_at: float = 0.0
    sim_ready: float = 0.0   # virtual arrival time (SimClock runs)
    # predicates whose verdict on this batch is a conservative PASS
    # (quarantined predicate or poison batch, see core/faults.py): the
    # rows were NOT filtered by these predicates, only flagged — consumers
    # needing exact semantics can drop or re-verify flagged batches
    passthrough: FrozenSet[str] = frozenset()

    @property
    def rows(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def empty(self) -> bool:
        return self.rows == 0

    def mark_visited(self, predicate: str) -> "RoutingBatch":
        return replace(self, visited=self.visited | {predicate})

    def mark_passthrough(self, predicate: str) -> "RoutingBatch":
        """Conservative pass-through verdict for ``predicate`` (fault
        quarantine): counts as VISITED — the termination invariant needs
        every predicate accounted for — but the rows are flagged rather
        than filtered, so no row is dropped on faulty evidence."""
        return replace(self, visited=self.visited | {predicate},
                       passthrough=self.passthrough | {predicate})

    def clear_passthrough(self, predicate: str) -> "RoutingBatch":
        """Lift ``predicate``'s conservative flag after re-verification
        (core/faults.py ReverifyQueue): the caller has ACTUALLY evaluated
        the predicate on these rows and will apply the real filter —
        ``visited`` is untouched, only the audit flag drops."""
        return replace(self, passthrough=self.passthrough - {predicate})

    def filter(self, mask: np.ndarray) -> "RoutingBatch":
        """Eager materialization: keep only rows where mask is True."""
        mask = np.asarray(mask, bool)
        assert mask.shape[0] == self.rows, (mask.shape, self.rows)
        data = {k: v[mask] for k, v in self.data.items()}
        return replace(self, data=data, row_ids=self.row_ids[mask])

    def column(self, name: str) -> np.ndarray:
        return self.data[name]

    def unvisited(self, predicates) -> list:
        return [p for p in predicates if p.name not in self.visited]

    def done(self, predicates) -> bool:
        return all(p.name in self.visited for p in predicates) or self.empty


def make_batch(data: Dict[str, np.ndarray], row_ids: Optional[np.ndarray] = None,
               **kw) -> RoutingBatch:
    rows = len(next(iter(data.values())))
    if row_ids is None:
        row_ids = np.arange(rows)
    return RoutingBatch(data=data, row_ids=np.asarray(row_ids), **kw)


# ------------------------- micro-batch coalescing ------------------------- #
@dataclass(frozen=True)
class BatchSegment:
    """One original batch's row span ``[start, stop)`` inside a fused batch.

    Holding the original ``RoutingBatch`` (not copies of its fields) is what
    makes ``split_back`` trivially bit-exact: the output is produced by
    ``batch.filter`` on the ORIGINAL object, so bid, visited set, warmup
    flag, and created_at are preserved by construction."""

    batch: RoutingBatch
    start: int
    stop: int

    @property
    def rows(self) -> int:
        return self.stop - self.start


def concat(batches: Sequence[RoutingBatch]) -> Tuple[RoutingBatch, List[BatchSegment]]:
    """Fuse ``batches`` into ONE transient batch for a single evaluation.

    Column-wise ``np.concatenate`` over identical schemas; returns the
    fused batch plus the per-batch segment boundaries for ``split_back``.
    Metadata of the fused batch is the conservative combination: visited =
    intersection (a predicate is "already evaluated" only if EVERY fused
    batch evaluated it), ``sim_ready`` = max (the fused launch cannot start
    before its last constituent arrived), ``warmup`` only if all are
    warmup, ``created_at`` = earliest.  A single-batch input is returned
    as-is (no copy)."""
    if not batches:
        raise ValueError("concat needs at least one batch")
    if len(batches) == 1:
        b = batches[0]
        return b, [BatchSegment(b, 0, b.rows)]
    cols = set(batches[0].data)
    for b in batches[1:]:
        if set(b.data) != cols:
            raise ValueError(
                f"cannot fuse batches with different schemas: "
                f"{sorted(cols)} vs {sorted(b.data)}"
            )
    data = {
        k: np.concatenate([b.data[k] for b in batches]) for k in batches[0].data
    }
    row_ids = np.concatenate([np.asarray(b.row_ids) for b in batches])
    fused = RoutingBatch(
        data=data,
        row_ids=row_ids,
        visited=frozenset.intersection(*[frozenset(b.visited) for b in batches]),
        warmup=all(b.warmup for b in batches),
        created_at=min(b.created_at for b in batches),
        sim_ready=max(b.sim_ready for b in batches),
    )
    segments, off = [], 0
    for b in batches:
        segments.append(BatchSegment(b, off, off + b.rows))
        off += b.rows
    return fused, segments


def split_back(
    segments: Sequence[BatchSegment],
    mask: np.ndarray,
    *,
    visit: Optional[str] = None,
    sim_ready: Optional[float] = None,
) -> List[RoutingBatch]:
    """Split a fused evaluation's row mask back into per-bid output batches.

    ``mask`` is the fused batch's boolean keep-mask (pre-filter row count);
    each segment's slice is applied to its ORIGINAL batch, then optionally
    marked ``visit``-ed and stamped with the fused launch's ``sim_ready``
    (the per-segment virtual finish under SimClock is the SHARED fused
    finish — one launch term, summed row terms).  Output order matches the
    segment (dequeue) order, so circulation order is preserved."""
    mask = np.asarray(mask, bool)
    total = segments[-1].stop if segments else 0
    if mask.shape[0] != total:
        raise ValueError(f"mask has {mask.shape[0]} rows, segments cover {total}")
    outs = []
    for seg in segments:
        out = seg.batch.filter(mask[seg.start:seg.stop])
        if visit is not None:
            out = out.mark_visited(visit)
        if sim_ready is not None:
            out = replace(out, sim_ready=sim_ready)
        outs.append(out)
    return outs
