"""RoutingBatch — the unit of data flowing through the AQP executor (§3.3).

Each batch carries a unique id (cheaper than hashing multi-dimensional
payloads, exactly as the paper argues), column data, per-row source ids (for
the reuse cache), and the set of predicates already evaluated. Eager
materialization: ``filter`` drops failing rows immediately so later
predicates see only survivors.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, FrozenSet, Optional

import numpy as np

_next_id = itertools.count()
_id_lock = threading.Lock()


def _new_id() -> int:
    with _id_lock:
        return next(_next_id)


@dataclass(frozen=True)
class RoutingBatch:
    data: Dict[str, np.ndarray]          # column -> (rows, ...) arrays
    row_ids: np.ndarray                  # (rows,) stable source ids (cache keys)
    bid: int = field(default_factory=_new_id)
    visited: FrozenSet[str] = frozenset()
    warmup: bool = False
    created_at: float = 0.0
    sim_ready: float = 0.0   # virtual arrival time (SimClock runs)

    @property
    def rows(self) -> int:
        return int(self.row_ids.shape[0])

    @property
    def empty(self) -> bool:
        return self.rows == 0

    def mark_visited(self, predicate: str) -> "RoutingBatch":
        return replace(self, visited=self.visited | {predicate})

    def filter(self, mask: np.ndarray) -> "RoutingBatch":
        """Eager materialization: keep only rows where mask is True."""
        mask = np.asarray(mask, bool)
        assert mask.shape[0] == self.rows, (mask.shape, self.rows)
        data = {k: v[mask] for k, v in self.data.items()}
        return replace(self, data=data, row_ids=self.row_ids[mask])

    def column(self, name: str) -> np.ndarray:
        return self.data[name]

    def unvisited(self, predicates) -> list:
        return [p for p in predicates if p.name not in self.visited]

    def done(self, predicates) -> bool:
        return all(p.name in self.visited for p in predicates) or self.empty


def make_batch(data: Dict[str, np.ndarray], row_ids: Optional[np.ndarray] = None,
               **kw) -> RoutingBatch:
    rows = len(next(iter(data.values())))
    if row_ids is None:
        row_ids = np.arange(rows)
    return RoutingBatch(data=data, row_ids=np.asarray(row_ids), **kw)
