"""Queues of the AQP executor (§3.2/§3.3).

CentralQueue implements the paper's deadlock prevention: the EDDY PULL may
insert only while the queue is < lambda (default 0.3) full, while predicate
workers may ALWAYS reinsert — completed batches can never be blocked out by
fresh ingest, so the cycle (pull -> route -> worker -> central) cannot
deadlock. Worker input queues are bounded short (default 2) to cap backlog,
exactly as in the paper.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Optional

LAMBDA_DEFAULT = 0.3


class ClosedError(RuntimeError):
    pass


class CentralQueue:
    def __init__(self, capacity: int = 64, lam: float = LAMBDA_DEFAULT):
        assert capacity > 0 and 0 < lam <= 1
        self.capacity = capacity
        self.lam = lam
        self._q: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    # -------------------- producer side -------------------- #
    def put_pull(self, item: Any, timeout: Optional[float] = None) -> bool:
        """EddyPull insert: allowed only below the lambda watermark."""
        limit = max(1, int(self.capacity * self.lam))
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._closed or len(self._q) < limit, timeout
            )
            if self._closed:
                raise ClosedError
            if not ok:
                return False
            self._q.append(item)
            self._cv.notify_all()
            return True

    def put_worker(self, item: Any) -> None:
        """Worker reinsert: always allowed (deadlock prevention)."""
        with self._cv:
            if self._closed:
                raise ClosedError
            self._q.append(item)
            self._cv.notify_all()

    def put_front(self, item: Any) -> None:
        """Head insert (used by the warmup circular flow)."""
        with self._cv:
            if self._closed:
                raise ClosedError
            self._q.appendleft(item)
            self._cv.notify_all()

    # -------------------- consumer side -------------------- #
    def get(self, timeout: Optional[float] = None) -> Any:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._closed or self._q, timeout)
            if self._q:
                item = self._q.popleft()
                self._cv.notify_all()
                return item
            if self._closed:
                raise ClosedError
            if not ok:
                raise TimeoutError
            raise AssertionError("unreachable")

    def __len__(self) -> int:
        with self._cv:
            return len(self._q)

    @property
    def fill_fraction(self) -> float:
        return len(self) / self.capacity

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


class BoundedQueue:
    """Short bounded FIFO for Laminar routers / workers (default len 2)."""

    def __init__(self, capacity: int = 2):
        self.capacity = capacity
        self._q: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    def put(self, item: Any, timeout: Optional[float] = None) -> bool:
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._closed or len(self._q) < self.capacity, timeout
            )
            if self._closed:
                raise ClosedError
            if not ok:
                return False
            self._q.append(item)
            self._cv.notify_all()
            return True

    def try_put(self, item: Any) -> bool:
        with self._cv:
            if self._closed:
                raise ClosedError
            if len(self._q) >= self.capacity:
                return False
            self._q.append(item)
            self._cv.notify_all()
            return True

    def get(self, timeout: Optional[float] = None) -> Any:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._closed or self._q, timeout)
            if self._q:
                item = self._q.popleft()
                self._cv.notify_all()
                return item
            if self._closed:
                raise ClosedError
            if not ok:
                raise TimeoutError
            raise AssertionError("unreachable")

    def __len__(self) -> int:
        with self._cv:
            return len(self._q)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
