"""Queues of the AQP executor (§3.2/§3.3) — lock-sharded.

CentralQueue implements the paper's deadlock prevention: the EDDY PULL may
insert only while the queue is < lambda (default 0.3) full, while predicate
workers may ALWAYS reinsert — completed batches can never be blocked out by
fresh ingest, so the cycle (pull -> route -> worker -> central) cannot
deadlock. Worker input queues are bounded short (default 2) to cap backlog,
exactly as in the paper.

SHARDING: with ``shards > 1`` the queue keeps one deque + condition
variable per routing shard behind a SINGLE lambda-watermark account (one
small counter lock, never held together with a stripe lock). Producers
touch exactly one stripe per insert (pull round-robins over the ACTIVE
stripes; workers reinsert to a batch's home stripe, ``bid % active``), so
the submit path of N shards never serializes on one condition variable.
Consumers ``get(shard=i)`` from their own stripe and — consumer-side ONLY —
steal from the longest sibling stripe when theirs drains. Stealing never
inserts, so the watermark invariant (worker reinserts always admitted,
pull gated below lambda) is exactly the single-deque one.

The old head-insert ``put_front`` is gone: the §4.1 warmup circular flow
pops from the head and reinserts at the TAIL via ``put_worker`` (pinned by
a regression test), so nothing ever inserted at the head.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any, List, Optional

LAMBDA_DEFAULT = 0.3

# A sharded consumer with an empty stripe re-scans its siblings for work to
# steal at this cadence; its own stripe's condition variable still wakes it
# immediately, so the poll only bounds cross-stripe pickup latency.
STEAL_POLL_S = 0.02


class ClosedError(RuntimeError):
    pass


class CentralQueue:
    """Bounded multi-producer queue with lambda-watermark pull gating.

    ``shards`` stripes each own a deque + condition variable; a single
    counter (its own lock, never nested with a stripe lock) carries the
    watermark/capacity accounting. ``shards=1`` reproduces the original
    single-deque behavior exactly.
    """

    def __init__(self, capacity: int = 64, lam: float = LAMBDA_DEFAULT,
                 shards: int = 1):
        assert capacity > 0 and 0 < lam <= 1 and shards >= 1
        self.capacity = capacity
        self.lam = lam
        self.shards = shards
        self._stripes: List[collections.deque] = [
            collections.deque() for _ in range(shards)
        ]
        self._cvs = [threading.Condition() for _ in range(shards)]
        # watermark/capacity account: guarded by its own condition variable;
        # producers blocked on space wait here, consumers notify on pop
        self._size_cv = threading.Condition()
        self._size = 0
        self._closed = False
        self._active = shards
        self._rr = itertools.count()
        self.steals = 0  # consumer-side cross-stripe pops (observability)

    # -------------------- stripe selection -------------------- #
    def set_active_shards(self, n: int) -> None:
        """Limit producer-side stripe assignment to the first ``n`` stripes
        (consumers may still drain/steal any stripe). Used by the shard set
        when it auto-scales mid-run."""
        self._active = max(1, min(n, self.shards))

    @property
    def active_shards(self) -> int:
        return self._active

    def _home(self, item: Any) -> int:
        """A batch's home stripe: affinity by batch id, so a batch cycles
        through one shard's loop and stealing is the only cross-shard path."""
        bid = getattr(item, "bid", None)
        if bid is None:
            return next(self._rr) % self._active
        return bid % self._active

    # -------------------- producer side -------------------- #
    def _reserve(self, limit: int, timeout: Optional[float]) -> bool:
        with self._size_cv:
            ok = self._size_cv.wait_for(
                lambda: self._closed or self._size < limit, timeout
            )
            if self._closed:
                raise ClosedError
            if not ok:
                return False
            self._size += 1
            return True

    def _unreserve(self) -> None:
        with self._size_cv:
            self._size -= 1
            self._size_cv.notify_all()

    def _append(self, idx: int, item: Any) -> None:
        with self._cvs[idx]:
            if self._closed:
                closed = True
            else:
                closed = False
                self._stripes[idx].append(item)
                self._cvs[idx].notify()
        if closed:  # raced with close(): undo the reservation, surface it
            self._unreserve()
            raise ClosedError

    def put_pull(self, item: Any, timeout: Optional[float] = None) -> bool:
        """EddyPull insert: allowed only below the lambda watermark.

        With no ``timeout`` this is a single blocking wait that wakes on
        space OR ``close()`` (raising ClosedError) — the pull thread never
        needs to spin-retry."""
        limit = max(1, int(self.capacity * self.lam))
        if not self._reserve(limit, timeout):
            return False
        self._append(next(self._rr) % self._active, item)
        return True

    def put_worker(self, item: Any, shard: Optional[int] = None) -> None:
        """Worker reinsert: always allowed (deadlock prevention)."""
        idx = self._home(item) if shard is None else shard % self.shards
        with self._cvs[idx]:
            if self._closed:
                raise ClosedError
            self._stripes[idx].append(item)
            self._cvs[idx].notify()
        with self._size_cv:
            self._size += 1

    def put(self, item: Any, timeout: Optional[float] = None,
            shard: Optional[int] = None) -> bool:
        """Capacity-bounded insert (no watermark) to a chosen stripe —
        the sharded OUTPUT queue path: each shard writes its own stripe so
        collection never serializes producers on one condition variable."""
        if not self._reserve(self.capacity, timeout):
            return False
        idx = (next(self._rr) if shard is None else shard) % self.shards
        self._append(idx, item)
        return True

    # -------------------- consumer side -------------------- #
    def _after_pop(self) -> None:
        with self._size_cv:
            self._size -= 1
            self._size_cv.notify_all()

    def get(self, timeout: Optional[float] = None, *, shard: int = 0) -> Any:
        """Pop for consumer ``shard``: own stripe first, else steal from the
        longest sibling stripe (consumer-side only — stealing never inserts,
        preserving the lambda-watermark invariant)."""
        idx = shard % self.shards
        if self.shards == 1:
            cv, q = self._cvs[0], self._stripes[0]
            with cv:
                ok = cv.wait_for(lambda: self._closed or q, timeout)
                if q:
                    item = q.popleft()
                elif self._closed:
                    raise ClosedError
                elif not ok:
                    raise TimeoutError
                else:
                    raise AssertionError("unreachable")
            self._after_pop()
            return item

        deadline = None if timeout is None else time.monotonic() + timeout
        cv = self._cvs[idx]
        while True:
            with cv:
                if self._stripes[idx]:
                    item = self._stripes[idx].popleft()
                    self._after_pop()
                    return item
            # steal: longest sibling stripe (length reads are unlocked —
            # a heuristic victim choice; the pop itself re-checks under
            # the victim's lock)
            victim = max(
                (j for j in range(self.shards) if j != idx),
                key=lambda j: len(self._stripes[j]),
            )
            if self._stripes[victim]:
                with self._cvs[victim]:
                    if self._stripes[victim]:
                        item = self._stripes[victim].popleft()
                        self.steals += 1
                        self._after_pop()
                        return item
            if self._closed:
                if not any(self._stripes):  # drain before raising
                    raise ClosedError
                continue
            now = time.monotonic()
            if deadline is not None and now >= deadline:
                raise TimeoutError
            wait = STEAL_POLL_S if deadline is None else min(
                STEAL_POLL_S, deadline - now
            )
            with cv:
                if not self._stripes[idx] and not self._closed:
                    cv.wait(wait)

    def __len__(self) -> int:
        with self._size_cv:
            return self._size

    @property
    def fill_fraction(self) -> float:
        return len(self) / self.capacity

    def close(self) -> None:
        with self._size_cv:
            self._closed = True
            self._size_cv.notify_all()
        for cv in self._cvs:
            with cv:
                cv.notify_all()


class BoundedQueue:
    """Short bounded FIFO for Laminar routers / workers (default len 2).

    Waiters are split across two condition variables on one lock: putters
    wait for SPACE, getters wait for an ITEM, and each side notifies
    exactly ONE waiter on the other. With N routing shards blocked in
    ``submit`` on a hot predicate's queue, a worker pop wakes a single
    submitter instead of thundering every blocked shard through the GIL —
    this is the submit-path serialization the sharded eddy core removes."""

    def __init__(self, capacity: int = 2):
        self.capacity = capacity
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._item = threading.Condition(self._lock)   # get() waiters
        self._space = threading.Condition(self._lock)  # put() waiters
        self._closed = False

    def put(self, item: Any, timeout: Optional[float] = None) -> bool:
        with self._space:
            ok = self._space.wait_for(
                lambda: self._closed or len(self._q) < self.capacity, timeout
            )
            if self._closed:
                raise ClosedError
            if not ok:
                return False
            self._q.append(item)
            self._item.notify()
            return True

    def try_put(self, item: Any) -> bool:
        with self._lock:
            if self._closed:
                raise ClosedError
            if len(self._q) >= self.capacity:
                return False
            self._q.append(item)
            self._item.notify()
            return True

    def get(self, timeout: Optional[float] = None) -> Any:
        with self._item:
            ok = self._item.wait_for(lambda: self._closed or self._q, timeout)
            if self._q:
                item = self._q.popleft()
                self._space.notify()
                return item
            if self._closed:
                raise ClosedError
            if not ok:
                raise TimeoutError
            raise AssertionError("unreachable")

    def get_many(self, max_items: int) -> list:
        """Non-blocking drain of up to ``max_items`` queued items.

        The micro-batch coalescing path: a worker that dequeued one batch
        opportunistically drains whatever else is already waiting so a
        single fused launch amortizes per-launch overhead.  Never blocks
        and never raises — returns ``[]`` when nothing is queued (a closed
        queue's remaining items are still drained; the caller's next
        blocking ``get`` surfaces ClosedError).  Each popped item wakes one
        blocked putter, exactly like ``get``, so producers refill the
        freed capacity without a thundering herd."""
        if max_items <= 0:
            return []
        with self._lock:
            n = min(max_items, len(self._q))
            items = [self._q.popleft() for _ in range(n)]
            for _ in range(n):
                self._space.notify()
            return items

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._item.notify_all()
            self._space.notify_all()
