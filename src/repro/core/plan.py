"""Logical query plan + rule-based optimization (§3.1).

Hydro's optimizer does only RULE-based work statically — predicate pushdown,
trivial (non-UDF) predicate ordering, cache/reuse wiring — and hands every
UDF-based conjunct to the AQP executor, whose routing replaces cost-based
static ordering. Mirrors the paper's EvaDB integration at the granularity
this repo needs: Scan -> Apply(UNNEST) -> [trivial filters] -> AQPFilter ->
Project.
"""
from __future__ import annotations

import operator
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.batch import RoutingBatch, make_batch
from repro.core.cache import ReuseCache
from repro.core.executor import AQPExecutor
from repro.core.udf import Predicate

_OPS = {
    "<=": operator.le, "<": operator.lt, ">=": operator.ge, ">": operator.gt,
    "==": operator.eq, "!=": operator.ne,
}


@dataclass(frozen=True)
class TrivialPredicate:
    """Non-UDF conjunct, e.g. rating <= 1. Free to evaluate -> pushed down."""

    column: str
    op: str
    value: object

    def mask(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        return np.asarray(_OPS[self.op](data[self.column], self.value), bool)


@dataclass
class Query:
    source: Iterable[Dict[str, np.ndarray]]     # scan (+ apply/UNNEST upstream)
    predicates: List[Predicate]                 # UDF-based conjuncts -> AQP
    trivial: List[TrivialPredicate] = field(default_factory=list)
    project: Optional[Sequence[str]] = None
    batch_rows: int = 10                        # paper's routing-batch size


@dataclass
class PhysicalPlan:
    query: Query
    executor: AQPExecutor
    description: List[str]

    def run(self) -> Iterator[RoutingBatch]:
        return self.executor.run(_batches(self.query))

    def collect_rows(self) -> Dict[str, np.ndarray]:
        cols: Dict[str, List[np.ndarray]] = {}
        ids: List[np.ndarray] = []
        keep = self.query.project
        for b in self.executor.run(_batches(self.query)):
            ids.append(b.row_ids)
            for k, v in b.data.items():
                if keep is None or k in keep:
                    cols.setdefault(k, []).append(v)
        out = {k: np.concatenate(v) if v else np.zeros((0,)) for k, v in cols.items()}
        out["_row_id"] = np.concatenate(ids) if ids else np.zeros((0,), np.int64)
        return out


def _batches(q: Query) -> Iterator[RoutingBatch]:
    """Scan -> trivial-filter pushdown -> routing batches (eager drop)."""
    buf: Dict[str, List] = {}
    ids: List[int] = []

    def flush():
        nonlocal buf, ids
        if not ids:
            return None
        data = {k: np.asarray(v) for k, v in buf.items()}
        rb = make_batch(data, np.asarray(ids))
        buf, ids = {}, []
        return rb

    for chunk in q.source:
        rows = len(chunk["_row_id"]) if "_row_id" in chunk else len(
            next(iter(chunk.values()))
        )
        mask = np.ones(rows, bool)
        for tp in q.trivial:  # pushdown: trivial predicates run at scan time
            mask &= tp.mask(chunk)
        for i in np.nonzero(mask)[0]:
            ids.append(int(chunk["_row_id"][i]) if "_row_id" in chunk else len(ids))
            for k, v in chunk.items():
                if k == "_row_id":
                    continue
                buf.setdefault(k, []).append(v[i])
            if len(ids) >= q.batch_rows:
                yield flush()
    tail = flush()
    if tail is not None:
        yield tail


def optimize(
    q: Query,
    *,
    cache: Optional[ReuseCache] = None,
    aqp: bool = True,
    executor_kwargs: Optional[dict] = None,
) -> PhysicalPlan:
    """Rule-based optimization -> physical plan.

    Rules applied (in order):
      1. TrivialPushdown — non-UDF conjuncts run at scan (lowest cost first;
         the paper's "trivial predicate reordering").
      2. CacheReuse — wire the reuse cache into UDF evaluation when present.
      3. AQPRule — wrap all UDF conjuncts into one AQP executor; disable
         warmup when only one predicate (nothing to reorder).
    """
    desc = []
    trivial = sorted(q.trivial, key=lambda t: 0)  # all trivially free
    if trivial:
        desc.append(f"TrivialPushdown({[t.column + t.op + str(t.value) for t in trivial]})")
    if cache is not None:
        desc.append("CacheReuse(on)")
    kw = dict(executor_kwargs or {})
    if not aqp:
        kw.setdefault("warmup", False)
        from repro.core.policies import EddyPolicy

        class _FixedOrder(EddyPolicy):
            name = "no-reordering"

            def rank(self, batch, preds, stats, cache):
                return preds  # conjunction order, left to right

        kw.setdefault("policy", _FixedOrder())
        desc.append("StaticPlan(no reordering)")
    else:
        desc.append("AQPRule(eddy+laminar)")
    executor = AQPExecutor(q.predicates, cache=cache, **kw)
    return PhysicalPlan(q, executor, desc)
