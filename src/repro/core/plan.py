"""Logical query plan + rule-based optimization (§3.1).

Hydro's optimizer does only RULE-based work statically — predicate pushdown,
trivial (non-UDF) predicate ordering, cache/reuse wiring — and hands every
UDF-based conjunct to the AQP executor, whose routing replaces cost-based
static ordering. Mirrors the paper's EvaDB integration at the granularity
this repo needs: Scan -> Apply(UNNEST) -> [trivial filters] -> AQPFilter ->
Project.
"""
from __future__ import annotations

import operator
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.batch import RoutingBatch, make_batch
from repro.core.cache import ReuseCache
from repro.core.executor import AQPExecutor
from repro.core.udf import Predicate

_OPS = {
    "<=": operator.le, "<": operator.lt, ">=": operator.ge, ">": operator.gt,
    "==": operator.eq, "!=": operator.ne,
}


@dataclass(frozen=True)
class TrivialPredicate:
    """Non-UDF conjunct, e.g. rating <= 1. Free to evaluate -> pushed down."""

    column: str
    op: str
    value: object

    def mask(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        return np.asarray(_OPS[self.op](data[self.column], self.value), bool)


@dataclass
class Query:
    source: Iterable[Dict[str, np.ndarray]]     # scan (+ apply/UNNEST upstream)
    predicates: List[Predicate]                 # UDF-based conjuncts -> AQP
    trivial: List[TrivialPredicate] = field(default_factory=list)
    project: Optional[Sequence[str]] = None
    batch_rows: int = 10                        # paper's routing-batch size


@dataclass
class PhysicalPlan:
    query: Query
    executor: AQPExecutor
    description: List[str]

    def run(self) -> Iterator[RoutingBatch]:
        return self.executor.run(_batches(self.query))

    def collect_rows(self) -> Dict[str, np.ndarray]:
        cols: Dict[str, List[np.ndarray]] = {}
        ids: List[np.ndarray] = []
        keep = self.query.project
        for b in self.executor.run(_batches(self.query)):
            ids.append(b.row_ids)
            for k, v in b.data.items():
                if keep is None or k in keep:
                    cols.setdefault(k, []).append(v)
        out = {k: np.concatenate(v) if v else np.zeros((0,)) for k, v in cols.items()}
        out["_row_id"] = np.concatenate(ids) if ids else np.zeros((0,), np.int64)
        return out


def batches_of(q: Query) -> Iterator[RoutingBatch]:
    """Public scan path: the routing-batch stream for ``q`` (trivial
    pushdown + re-chunking).  The QueryService CLI (launch/serve.py)
    submits this stream directly so single-query and multi-tenant
    execution share one scan implementation."""
    return _batches(q)


def _batches(q: Query) -> Iterator[RoutingBatch]:
    """Scan -> trivial-filter pushdown -> routing batches (eager drop).

    Vectorized: surviving rows are selected with one boolean-mask slice per
    chunk and re-chunked into ``batch_rows``-sized batches by array
    slicing — no per-row Python loop. Batch boundaries are identical to
    the row-at-a-time formulation: rows flow in arrival order and every
    batch except the tail holds exactly ``batch_rows`` rows. Rows from
    chunks without a ``_row_id`` column get their position within the
    emitted batch as a synthesized id (the historical behavior; a source
    may even mix chunks with and without ids)."""
    per = q.batch_rows
    pend_cols: List[Dict[str, np.ndarray]] = []   # filtered chunk slices
    pend_ids: List[Optional[np.ndarray]] = []     # None = synthesize
    pending = 0

    def drain(cols_parts, id_parts, rows, final):
        """Concatenate pending slices; yield full batches (+ tail if final)."""
        data = {k: np.concatenate([p[k] for p in cols_parts])
                for k in cols_parts[0]}
        # Per-part ids: real _row_ids pass through; missing ones become
        # the row's position within its batch. Drains always start at a
        # batch boundary (the carry is < per and goes to the front), so
        # position-in-batch == running-offset % per.
        parts, off = [], 0
        for p, ids in zip(cols_parts, id_parts):
            n = len(next(iter(p.values()))) if p else (
                len(ids) if ids is not None else 0
            )
            parts.append(ids if ids is not None
                         else np.arange(off, off + n, dtype=np.int64) % per)
            off += n
        all_ids = np.concatenate(parts) if parts else np.zeros(0, np.int64)
        out = []
        n_full = rows // per
        for j in range(n_full):
            sl = slice(j * per, (j + 1) * per)
            out.append(make_batch({k: v[sl] for k, v in data.items()},
                                  all_ids[sl]))
        rem = rows - n_full * per
        if rem and final:
            out.append(make_batch(
                {k: v[n_full * per:] for k, v in data.items()},
                all_ids[n_full * per:]))
            rem = 0
        carry_cols = ([{k: v[n_full * per:] for k, v in data.items()}]
                      if rem else [])
        # the carry keeps its (already position-synthesized or real) ids:
        # it sits at position 0.. of the NEXT batch either way
        carry_ids = [all_ids[n_full * per:]] if rem else []
        return out, carry_cols, carry_ids, rem

    for chunk in q.source:
        rows = len(chunk["_row_id"]) if "_row_id" in chunk else len(
            next(iter(chunk.values()))
        )
        mask = np.ones(rows, bool)
        for tp in q.trivial:  # pushdown: trivial predicates run at scan time
            mask &= tp.mask(chunk)
        idx = np.nonzero(mask)[0]
        if not idx.size:
            continue
        pend_cols.append({k: np.asarray(v)[idx] for k, v in chunk.items()
                          if k != "_row_id"})
        pend_ids.append(np.asarray(chunk["_row_id"])[idx].astype(np.int64)
                        if "_row_id" in chunk else None)
        pending += idx.size
        if pending >= per:
            full, pend_cols, pend_ids, pending = drain(
                pend_cols, pend_ids, pending, final=False
            )
            yield from full
    if pending:
        tail, _, _, _ = drain(pend_cols, pend_ids, pending, final=True)
        yield from tail


def optimize(
    q: Query,
    *,
    cache: Optional[ReuseCache] = None,
    aqp: bool = True,
    executor_kwargs: Optional[dict] = None,
) -> PhysicalPlan:
    """Rule-based optimization -> physical plan.

    Rules applied (in order):
      1. TrivialPushdown — non-UDF conjuncts run at scan (lowest cost first;
         the paper's "trivial predicate reordering").
      2. CacheReuse — wire the reuse cache into UDF evaluation when present.
      3. AQPRule — wrap all UDF conjuncts into one AQP executor; disable
         warmup when only one predicate (nothing to reorder).
    """
    desc = []
    trivial = list(q.trivial)  # all trivially free: conjunction order as-is
    if trivial:
        desc.append(f"TrivialPushdown({[t.column + t.op + str(t.value) for t in trivial]})")
    if cache is not None:
        desc.append("CacheReuse(on)")
    kw = dict(executor_kwargs or {})
    if not aqp:
        kw.setdefault("warmup", False)
        from repro.core.policies import EddyPolicy

        class _FixedOrder(EddyPolicy):
            name = "no-reordering"

            def rank(self, batch, preds, stats, cache):
                return preds  # conjunction order, left to right

        kw.setdefault("policy", _FixedOrder())
        desc.append("StaticPlan(no reordering)")
    else:
        desc.append("AQPRule(eddy+laminar)")
    executor = AQPExecutor(q.predicates, cache=cache, **kw)
    return PhysicalPlan(q, executor, desc)
