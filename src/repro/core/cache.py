"""Reuse cache for UDF results (§4.3, UC2).

Keyed by (udf_name, row_id) — row ids are stable source identifiers (e.g.
video frame id x object index), so results cached by one query are reused by
later queries over overlapping ranges (the paper's exploratory-analysis
pattern). ``probe`` returns the per-batch hit mask in O(rows) so the
REUSE-AWARE router can estimate

    estimated_cost = (1 - cache_hit_rate) * cost_of_computing_UDF

before routing, per the paper. Optionally spills to disk (npz) to mirror the
paper's on-disk KV store.
"""
from __future__ import annotations

import os
import threading
from typing import Dict, Optional, Tuple

import numpy as np


class ReuseCache:
    def __init__(self, path: Optional[str] = None):
        self._data: Dict[str, Dict[int, np.ndarray]] = {}
        self._lock = threading.RLock()
        self.path = path
        if path and os.path.exists(path):
            self._load()

    # ----------------------------- core ----------------------------- #
    def probe(self, udf: str, row_ids: np.ndarray) -> Tuple[np.ndarray, list]:
        """(hit_mask (rows,), values list aligned to rows; None on miss)."""
        with self._lock:
            table = self._data.get(udf, {})
            hits = np.zeros(len(row_ids), bool)
            vals = []
            for i, rid in enumerate(np.asarray(row_ids).tolist()):
                v = table.get(int(rid))
                hits[i] = v is not None
                vals.append(v)
            return hits, vals

    def hit_rate(self, udf: str, row_ids: np.ndarray) -> float:
        hits, _ = self.probe(udf, row_ids)
        return float(hits.mean()) if len(hits) else 0.0

    def put(self, udf: str, row_ids: np.ndarray, values: np.ndarray) -> None:
        with self._lock:
            table = self._data.setdefault(udf, {})
            for rid, v in zip(np.asarray(row_ids).tolist(), values):
                table[int(rid)] = np.asarray(v)

    def __contains__(self, udf: str) -> bool:
        with self._lock:
            return udf in self._data and bool(self._data[udf])

    def size(self, udf: str) -> int:
        with self._lock:
            return len(self._data.get(udf, {}))

    # ----------------------------- disk ----------------------------- #
    def flush(self) -> None:
        if not self.path:
            return
        with self._lock:
            blob = {}
            for udf, table in self._data.items():
                if not table:
                    continue
                ids = np.array(sorted(table), dtype=np.int64)
                vals = np.stack([table[int(i)] for i in ids])
                blob[f"{udf}__ids"] = ids
                blob[f"{udf}__vals"] = vals
            np.savez(self.path, **blob)

    def _load(self) -> None:
        data = np.load(self.path, allow_pickle=False)
        names = {k[: -len("__ids")] for k in data.files if k.endswith("__ids")}
        for udf in names:
            ids = data[f"{udf}__ids"]
            vals = data[f"{udf}__vals"]
            self._data[udf] = {int(i): v for i, v in zip(ids, vals)}
