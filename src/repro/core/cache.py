"""Reuse caches for UDF results (§4.3, UC2) — id-keyed, content-keyed, layered.

Three classes, one probe/put surface:

``ReuseCache``
    Keyed by (udf_name, row_id) — row ids are stable source identifiers
    (e.g. video frame id x object index), so results cached by one query
    are reused by later queries over overlapping ranges (the paper's
    exploratory-analysis pattern). Optionally spills to disk (npz) to
    mirror the paper's on-disk KV store. Hardened:

    * the ``path`` is normalized to the ``.npz`` extension once at
      construction (``np.savez`` appends it on write, so an extension-less
      path used to read back cold);
    * ``flush`` groups rows by (dtype, shape) so heterogeneous values
      (e.g. a detector returning variable-length boxes) round-trip instead
      of crashing ``np.stack``;
    * ``flush`` writes to a temp file and ``os.replace``s it — a crash
      mid-write never corrupts the previous snapshot — and ``_load``
      tolerates a corrupt/empty file by starting cold with a warning;
    * ``probe`` vectorizes membership over a sorted id index and
      ``hit_rate`` takes a values-free path (``hit_mask``) — both sit on
      the REUSE-AWARE routing hot path.

``ContentHashCache``
    Keyed by (udf_name, digest of the row PAYLOAD), so repeated or
    overlapping queries hit even when their row ids differ — the same
    frame re-ingested under a new scan id still skips the kernel launch.
    Knobs: ``ttl_s`` (entries older than the TTL read as misses and are
    evicted lazily; ``None`` = never expire) and explicit
    ``invalidate(udf=None)`` (drop one UDF's entries, or everything —
    the hook for upstream data changes the digest cannot see, e.g. a
    model-weight update that changes what the UDF would return).

``LayeredReuseCache``
    The cross-query composition: an id layer (fast, disk-spillable) over a
    content layer (id-agnostic, TTL-bounded). Probes check ids first and
    fall through to content digests for the misses; content hits are
    promoted into the id layer under the probing query's row ids so the
    next probe for the same ids is a pure index lookup. This is what the
    REUSE-AWARE policy reads: ``hit_rate(udf, row_ids, data=...)`` feeds
    the paper's ``(1 - hit_rate) x cost`` routing estimate with real
    cross-run hits.

Digests cover only the UDF's input columns (callers pass the
column-restricted batch data), include dtype/shape, and use 64-bit
blake2b — one hash per row at Hydro's ~10-row routing-batch granularity.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
import warnings
from typing import Dict, List, Optional, Tuple

import numpy as np


def row_digests(data: Dict[str, np.ndarray]) -> np.ndarray:
    """(rows,) int64 content digests over the given columns.

    Column name, dtype, and trailing shape are folded into each row's
    digest so reinterpretations of the same bytes cannot collide."""
    cols = sorted(data)
    arrs = [np.ascontiguousarray(np.asarray(data[c])) for c in cols]
    rows = len(arrs[0]) if arrs else 0
    out = np.empty(rows, np.int64)
    for i in range(rows):
        h = hashlib.blake2b(digest_size=8)
        for c, a in zip(cols, arrs):
            r = a[i]
            h.update(repr((c, a.dtype.str, r.shape)).encode())
            h.update(r.tobytes())
        out[i] = int.from_bytes(h.digest(), "little", signed=True)
    return out


class ReuseCache:
    """Id-keyed result cache; see module docstring for the layer picture."""

    def __init__(self, path: Optional[str] = None):
        self._data: Dict[str, Dict[int, np.ndarray]] = {}
        # per-udf sorted id arrays for vectorized probes; rebuilt lazily
        # after a put invalidates them
        self._index: Dict[str, np.ndarray] = {}
        self._lock = threading.RLock()
        # np.savez appends ".npz" when the target lacks it, so an
        # un-normalized path would WRITE cache.npz but READ (and miss) the
        # literal path — the next process would silently start cold.
        if path and not path.endswith(".npz"):
            path += ".npz"
        self.path = path
        if path and os.path.exists(path):
            self._load()

    # ----------------------------- core ----------------------------- #
    def _sorted_ids(self, udf: str) -> np.ndarray:
        idx = self._index.get(udf)
        if idx is None:
            table = self._data.get(udf, {})
            idx = np.fromiter(table.keys(), np.int64, count=len(table))
            idx.sort()
            self._index[udf] = idx
        return idx

    def _hit_mask_locked(self, udf: str, ids: np.ndarray) -> np.ndarray:
        keys = self._sorted_ids(udf)
        if keys.size == 0 or ids.size == 0:
            return np.zeros(ids.size, bool)
        pos = np.searchsorted(keys, ids)
        pos = np.minimum(pos, keys.size - 1)
        return keys[pos] == ids

    @staticmethod
    def _as_ids(row_ids: np.ndarray) -> np.ndarray:
        return np.asarray(row_ids).astype(np.int64, copy=False).ravel()

    def hit_mask(self, udf: str, row_ids: np.ndarray) -> np.ndarray:
        """Vectorized per-row hit mask WITHOUT materializing values."""
        with self._lock:
            return self._hit_mask_locked(udf, self._as_ids(row_ids))

    def probe(self, udf: str, row_ids: np.ndarray) -> Tuple[np.ndarray, list]:
        """(hit_mask (rows,), values list aligned to rows; None on miss)."""
        with self._lock:
            ids = self._as_ids(row_ids)
            hits = self._hit_mask_locked(udf, ids)
            table = self._data.get(udf, {})
            vals: List[Optional[np.ndarray]] = [
                table[r] if h else None
                for r, h in zip(ids.tolist(), hits.tolist())
            ]
            return hits, vals

    def hit_rate(self, udf: str, row_ids: np.ndarray, data=None) -> float:
        """Values-free: one vectorized membership test, nothing fetched.

        ``data`` is accepted (and ignored) so callers can pass batch
        payloads uniformly; the content-aware layers actually use it."""
        hits = self.hit_mask(udf, row_ids)
        return float(hits.mean()) if hits.size else 0.0

    def put(self, udf: str, row_ids: np.ndarray, values) -> None:
        with self._lock:
            table = self._data.setdefault(udf, {})
            for rid, v in zip(self._as_ids(row_ids).tolist(), values):
                table[rid] = np.asarray(v)
            self._index.pop(udf, None)

    # batch-aware aliases: the worker calls these uniformly; the id-keyed
    # base ignores the payload, the layered cache digests it
    def probe_batch(self, udf: str, row_ids: np.ndarray,
                    data=None) -> Tuple[np.ndarray, list]:
        return self.probe(udf, row_ids)

    def put_batch(self, udf: str, row_ids: np.ndarray, data, values) -> None:
        self.put(udf, row_ids, values)

    def invalidate(self, udf: Optional[str] = None) -> None:
        with self._lock:
            if udf is None:
                self._data.clear()
                self._index.clear()
            else:
                self._data.pop(udf, None)
                self._index.pop(udf, None)

    def __contains__(self, udf: str) -> bool:
        with self._lock:
            return udf in self._data and bool(self._data[udf])

    def size(self, udf: str) -> int:
        with self._lock:
            return len(self._data.get(udf, {}))

    # ----------------------------- disk ----------------------------- #
    def flush(self) -> None:
        """Atomic snapshot: rows grouped by (dtype, shape) so ragged values
        round-trip; temp file + ``os.replace`` so a crash mid-write leaves
        the previous snapshot intact."""
        if not self.path:
            return
        with self._lock:
            blob = {}
            for udf, table in self._data.items():
                if not table:
                    continue
                groups: Dict[tuple, List[int]] = {}
                for rid, v in table.items():
                    groups.setdefault((v.dtype.str, v.shape), []).append(rid)
                for gi, key in enumerate(sorted(groups)):
                    ids = np.array(sorted(groups[key]), dtype=np.int64)
                    vals = np.stack([table[int(i)] for i in ids])
                    blob[f"{udf}__g{gi}__ids"] = ids
                    blob[f"{udf}__g{gi}__vals"] = vals
            tmp = self.path + ".tmp.npz"  # ends in .npz: savez won't rename
            try:
                np.savez(tmp, **blob)
                os.replace(tmp, self.path)
            finally:
                if os.path.exists(tmp):
                    os.remove(tmp)

    def _load(self) -> None:
        try:
            with np.load(self.path, allow_pickle=False) as data:
                for key in data.files:
                    if not key.endswith("__ids"):
                        continue
                    base = key[: -len("__ids")]
                    # grouped layout "udf__g<N>"; legacy files are "udf"
                    udf, sep, g = base.rpartition("__g")
                    if not (sep and g.isdigit()):
                        udf = base
                    ids = data[key]
                    vals = data[base + "__vals"]
                    table = self._data.setdefault(udf, {})
                    for i, v in zip(ids, vals):
                        table[int(i)] = v
        except Exception as e:
            # a corrupt/truncated snapshot (e.g. a crash before flush went
            # atomic) must not take the process down at construction —
            # starting cold only costs recomputation
            self._data.clear()
            warnings.warn(
                f"ReuseCache: could not load {self.path!r} ({e!r}); "
                "starting cold"
            )
        self._index.clear()


class ContentHashCache:
    """Content-digest-keyed result cache with TTL + explicit invalidation.

    Knobs: ``ttl_s`` — seconds an entry stays probeable (``None`` = no
    expiry); entries past the TTL read as misses and are evicted lazily on
    probe. ``clock`` is injectable for deterministic tests. Memory-only:
    cross-process persistence belongs to the id layer (``ReuseCache``)
    after promotion."""

    def __init__(self, ttl_s: Optional[float] = None, *,
                 clock=time.monotonic):
        self.ttl_s = ttl_s
        self.clock = clock
        self._data: Dict[str, Dict[int, Tuple[np.ndarray, float]]] = {}
        self._lock = threading.RLock()

    def _fresh(self, stamped: Tuple[np.ndarray, float], now: float) -> bool:
        return self.ttl_s is None or (now - stamped[1]) <= self.ttl_s

    def probe_digests(self, udf: str,
                      digests: np.ndarray) -> Tuple[np.ndarray, list]:
        with self._lock:
            table = self._data.get(udf, {})
            now = self.clock()
            hits = np.zeros(len(digests), bool)
            vals: List[Optional[np.ndarray]] = [None] * len(digests)
            for i, d in enumerate(np.asarray(digests).tolist()):
                stamped = table.get(d)
                if stamped is None:
                    continue
                if not self._fresh(stamped, now):
                    del table[d]  # lazy TTL eviction
                    continue
                hits[i] = True
                vals[i] = stamped[0]
            return hits, vals

    def hit_mask_digests(self, udf: str, digests: np.ndarray) -> np.ndarray:
        with self._lock:
            table = self._data.get(udf, {})
            now = self.clock()
            return np.fromiter(
                (d in table and self._fresh(table[d], now)
                 for d in np.asarray(digests).tolist()),
                bool, count=len(digests),
            )

    def put_digests(self, udf: str, digests: np.ndarray, values) -> None:
        with self._lock:
            table = self._data.setdefault(udf, {})
            now = self.clock()
            for d, v in zip(np.asarray(digests).tolist(), values):
                table[d] = (np.asarray(v), now)

    # batch-payload convenience surface (mirrors ReuseCache)
    def probe_batch(self, udf: str, row_ids: np.ndarray,
                    data=None) -> Tuple[np.ndarray, list]:
        if not data:
            return np.zeros(len(np.asarray(row_ids)), bool), [None] * len(
                np.asarray(row_ids))
        return self.probe_digests(udf, row_digests(data))

    def put_batch(self, udf: str, row_ids: np.ndarray, data, values) -> None:
        if data:
            self.put_digests(udf, row_digests(data), values)

    def hit_rate(self, udf: str, row_ids: np.ndarray, data=None) -> float:
        if not data:
            return 0.0
        mask = self.hit_mask_digests(udf, row_digests(data))
        return float(mask.mean()) if mask.size else 0.0

    def invalidate(self, udf: Optional[str] = None) -> None:
        """Explicit invalidation: one UDF's entries, or everything."""
        with self._lock:
            if udf is None:
                self._data.clear()
            else:
                self._data.pop(udf, None)

    def size(self, udf: str) -> int:
        with self._lock:
            return len(self._data.get(udf, {}))

    def __contains__(self, udf: str) -> bool:
        with self._lock:
            return udf in self._data and bool(self._data[udf])


class LayeredReuseCache:
    """Id layer over content layer; the cross-query reuse surface.

    ``path`` spills the id layer to disk (same npz store as ``ReuseCache``);
    ``ttl_s``/``clock`` configure the content layer. Pre-built layers can
    be passed instead (``ids=``/``content=``) to share either across
    executors."""

    def __init__(self, path: Optional[str] = None, *,
                 ids: Optional[ReuseCache] = None,
                 content: Optional[ContentHashCache] = None,
                 ttl_s: Optional[float] = None, clock=time.monotonic):
        self.ids = ids if ids is not None else ReuseCache(path)
        self.content = (content if content is not None
                        else ContentHashCache(ttl_s=ttl_s, clock=clock))

    # --------------------------- probing --------------------------- #
    def probe_batch(self, udf: str, row_ids: np.ndarray,
                    data=None) -> Tuple[np.ndarray, list]:
        hits, vals = self.ids.probe(udf, row_ids)
        if data and not hits.all():
            digs = row_digests(data)
            miss = np.nonzero(~hits)[0]
            chits, cvals = self.content.probe_digests(udf, digs[miss])
            promoted_ids, promoted_vals = [], []
            row_arr = np.asarray(row_ids).ravel()
            for j, i in enumerate(miss.tolist()):
                if chits[j]:
                    hits[i] = True
                    vals[i] = cvals[j]
                    promoted_ids.append(int(row_arr[i]))
                    promoted_vals.append(cvals[j])
            if promoted_ids:
                # promotion: the NEXT probe for these ids is a pure
                # sorted-index lookup in the id layer
                self.ids.put(udf, np.asarray(promoted_ids), promoted_vals)
        return hits, vals

    def probe(self, udf: str, row_ids: np.ndarray) -> Tuple[np.ndarray, list]:
        return self.ids.probe(udf, row_ids)

    def hit_mask(self, udf: str, row_ids: np.ndarray) -> np.ndarray:
        return self.ids.hit_mask(udf, row_ids)

    def hit_rate(self, udf: str, row_ids: np.ndarray, data=None) -> float:
        """Values-free across BOTH layers — the ReuseAware routing input."""
        mask = self.ids.hit_mask(udf, row_ids)
        if data and not mask.all():
            digs = row_digests(data)
            miss = np.nonzero(~mask)[0]
            cmask = self.content.hit_mask_digests(udf, digs[miss])
            mask = mask.copy()
            mask[miss[cmask]] = True
        return float(mask.mean()) if mask.size else 0.0

    # --------------------------- writing --------------------------- #
    def put(self, udf: str, row_ids: np.ndarray, values) -> None:
        self.ids.put(udf, row_ids, values)

    def put_batch(self, udf: str, row_ids: np.ndarray, data, values) -> None:
        self.ids.put(udf, row_ids, values)
        if data:
            self.content.put_digests(udf, row_digests(data), values)

    def invalidate(self, udf: Optional[str] = None) -> None:
        self.ids.invalidate(udf)
        self.content.invalidate(udf)

    # --------------------------- inspection ------------------------ #
    def size(self, udf: str) -> int:
        return self.ids.size(udf)

    def __contains__(self, udf: str) -> bool:
        return udf in self.ids or udf in self.content

    @property
    def path(self) -> Optional[str]:
        return self.ids.path

    def flush(self) -> None:
        self.ids.flush()
