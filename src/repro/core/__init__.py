"""Hydro: adaptive query processing of ML queries — the paper's contribution.

Public surface:
  RoutingBatch / make_batch          — §3.3 batch + metadata
  CentralQueue / BoundedQueue        — §3.2/§3.3 queues, lambda watermark
  StatsBoard                         — §3.3 runtime statistics
  UDF / Predicate                    — ML UDF wrappers (shape-bucketed)
  ReuseCache / ContentHashCache / LayeredReuseCache — §4.3 result reuse
    (id-keyed, content-hash + TTL, and the cross-query layered composition)
  StatsStore / canonical_fingerprint — cross-query persistent statistics
    (fingerprint -> age-decayed EMA cost/selectivity, warm-starts runs)
  policies: CostDriven / ScoreDriven / SelectivityDriven / ReuseAware /
            HydroPolicy; RoundRobin / DataAware / DeviceAlternating;
            PressureRanked / StaticPartition (arbiter)
  DevicePool / ResourceArbiter       — §5.2 elastic cross-predicate leasing
  LaminarRouter (GACU) / EddyShardSet / AQPExecutor — §3.2, §4, §5
    (the eddy core runs as N routing shards with work-stealing and merged
    statistics; single-shard is the deterministic default — see core/eddy.py)
  Query / optimize / PhysicalPlan    — §3.1 rule-based plan -> AQP plan
  SimClock / WallClock               — deterministic scheduling evaluation
  CoalesceConfig / CoalescePlanner   — §5.1 adaptive micro-batch coalescing
    (fuse queued batches into one launch; executor knob ``coalesce=``)
  FaultPlan / FaultLedger / FaultConfig / LaunchWatchdog / ReverifyQueue —
    fault injection, per-predicate failure statistics (with recovery
    probes un-quarantining on success), retry/degrade/quarantine policy,
    hung-launch detection, and the pass-through re-verification queue
    (executor knobs ``on_fault=`` / ``reverify=``; see core/faults.py)
  QuerySession / urgency_weight      — restartable per-query sessions and
    deadline/priority arbitration urgency (multi-tenant QueryService —
    the serving layer itself lives in repro.launch.serve)
  vectorized (two_stage_filter / cascade_filter) — TPU-native short-circuit
"""
from repro.core.batch import (  # noqa: F401
    BatchSegment,
    RoutingBatch,
    concat,
    make_batch,
    split_back,
)
from repro.core.coalesce import (  # noqa: F401
    CoalesceConfig,
    CoalescePlanner,
    FusePlan,
)
from repro.core.cache import (  # noqa: F401
    ContentHashCache,
    LayeredReuseCache,
    ReuseCache,
    row_digests,
)
from repro.core.eddy import (  # noqa: F401
    SHARD_AUTO_MAX,
    SHARD_AUTO_THRESHOLD_BPS,
    EddyShardSet,
    InFlightTracker,
)
from repro.core.executor import AQPExecutor, QuerySession  # noqa: F401
from repro.core.faults import (  # noqa: F401
    CorruptOutputError,
    FaultConfig,
    FaultLedger,
    FaultPlan,
    InjectedFault,
    LaunchWatchdog,
    ReverifyQueue,
)
from repro.core.laminar import GACU_MAX_WORKERS, LaminarRouter  # noqa: F401
from repro.core.plan import (  # noqa: F401
    PhysicalPlan,
    Query,
    TrivialPredicate,
    batches_of,
    optimize,
)
from repro.core.policies import (  # noqa: F401
    ArbiterPolicy,
    CostDriven,
    DataAware,
    DeviceAlternating,
    HydroPolicy,
    PressureRanked,
    ReuseAware,
    RoundRobin,
    ScoreDriven,
    SelectivityDriven,
    StaticPartition,
    urgency_weight,
)
from repro.core.queues import BoundedQueue, CentralQueue  # noqa: F401
from repro.core.resources import (  # noqa: F401
    DRAIN_THRESHOLD_S,
    DevicePool,
    ResourceArbiter,
)
from repro.core.simclock import SimClock, WallClock  # noqa: F401
from repro.core.stats import PredicateStats, StatsBoard  # noqa: F401
from repro.core.statstore import (  # noqa: F401
    COST_MODEL_VERSION,
    StatsStore,
    canonical_fingerprint,
    fingerprint_of,
)
from repro.core.udf import UDF, Predicate  # noqa: F401
