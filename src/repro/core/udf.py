"""UDF wrapper: the bridge between Hydro and jitted JAX models (§5.1).

The paper's "batch-agnostic parallelization" problem (variable input dims
defeat batching; third-party single-image APIs underutilize the GPU) maps to
TPU/XLA as the RECOMPILATION problem: every new shape compiles a new
executable. The wrapper therefore (a) canonicalizes spatial dims upstream
(data/video.crop_to_canonical) and (b) buckets row counts to powers of two,
so each worker holds a handful of executables that serve any batch.

GACU lazy activation (§5.1): ``ensure_ready`` is only called when the first
batch is routed to a worker — context allocation is greedy, executable
compilation + weight residency is conservative.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence

import numpy as np


def bucket_rows(n: int, *, minimum: int = 1) -> int:
    b = max(minimum, 1)
    while b < n:
        b *= 2
    return b


def pad_rows(v: np.ndarray, target: int) -> np.ndarray:
    """Pad ``v`` to ``target`` rows by repeating its first row (edge fill).

    Single ``np.empty`` allocation + two fills — the old
    ``np.concatenate([v, np.repeat(v[:1], ...)])`` allocated the repeat
    block AND the concatenation result on every bucketed launch.  No-copy
    fast path when ``v`` is already at ``target`` rows."""
    rows = v.shape[0]
    if rows == target:
        return v
    if rows > target:
        raise ValueError(f"cannot pad {rows} rows down to {target}")
    out = np.empty((target,) + v.shape[1:], v.dtype)
    out[:rows] = v
    out[rows:] = v[:1]  # broadcast edge fill, no intermediate repeat copy
    return out


@dataclass
class UDF:
    """A (possibly expensive) ML function over batch columns.

    fn: maps dict[col -> np.ndarray (rows, ...)] -> np.ndarray (rows, ...).
    cost_model: simulated seconds for `rows` rows (SimClock benchmarks);
    proxy_cost: data-aware load units for a batch (paper: input size).
    """

    name: str
    fn: Callable[[Dict[str, np.ndarray]], np.ndarray]
    columns: Sequence[str]
    resource: str = "cpu"                       # e.g. "cpu", "tpu:0"
    bucket: bool = True
    warm_fn: Optional[Callable[[], None]] = None  # lazy init (GACU)
    cost_model: Optional[Callable[[int], float]] = None
    proxy_cost: Optional[Callable[[Dict[str, np.ndarray]], float]] = None
    # canonical cross-process identity (kernel + config + cost-model
    # version, see core/statstore.canonical_fingerprint) keying the
    # persistent statistics store; None falls back to udf:<name>
    fingerprint: Optional[str] = None
    # Graceful degradation (core/faults.py): a reference/interpret-mode
    # implementation of ``fn``; ``degrade()`` flips evaluation onto it
    # when the compiled path fails repeatedly. None == nothing to fall
    # back to (degrade-mode fault handling then quarantines instead).
    fallback_fn: Optional[Callable[[Dict[str, np.ndarray]], np.ndarray]] = None
    degraded: bool = field(default=False, repr=False)
    _ready: bool = field(default=False, repr=False)
    # output dtype + trailing shape, learned from the first evaluation so
    # zero-row calls don't have to launch the kernel just for metadata
    _out_spec: Optional[tuple] = field(default=None, repr=False)

    def ensure_ready(self) -> None:
        if not self._ready:
            if self.warm_fn is not None:
                # A warm_fn may return a sample output (the library's
                # one-row probes do); learn the output spec from it so the
                # zero-row path never needs its own probe launch.
                probe = self.warm_fn()
                if probe is not None and self._out_spec is None:
                    probe = np.asarray(probe)
                    self._out_spec = (
                        probe.dtype, probe.shape[1:] if probe.ndim else ()
                    )
            self._ready = True

    @property
    def out_spec(self) -> Optional[tuple]:
        """(dtype, trailing shape) learned from the first evaluation, or
        None before any launch — the worker's corruption check compares
        subsequent outputs against it."""
        return self._out_spec

    def degrade(self) -> bool:
        """Switch evaluation to ``fallback_fn`` (the reference path).

        Returns True if a fallback exists and the switch happened; False
        when there is nothing to degrade to (caller falls through to
        quarantine). Sticky for the UDF's lifetime — a degraded
        executable does not get retried."""
        if self.fallback_fn is None or self.degraded:
            return False
        self.degraded = True
        return True

    def _active_fn(self) -> Callable[[Dict[str, np.ndarray]], np.ndarray]:
        if self.degraded and self.fallback_fn is not None:
            return self.fallback_fn
        return self.fn

    def proxy(self, data: Dict[str, np.ndarray]) -> float:
        if self.proxy_cost is not None:
            return float(self.proxy_cost(data))
        first = data[self.columns[0]]
        return float(np.asarray(first).size)  # default: input size

    def __call__(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        self.ensure_ready()
        fn = self._active_fn()
        cols = {c: np.asarray(data[c]) for c in self.columns}
        rows = len(next(iter(cols.values())))
        if rows == 0:
            if self._out_spec is None:
                # Probe with ONE synthesized row, never genuinely empty
                # arrays: bucketing kernels assert on zero-sized grids, and
                # ``v[:1]`` of an empty column is still empty. The learned
                # dtype/trailing shape is cached so this costs one launch
                # per UDF lifetime, not one per empty batch.
                probe_cols = {
                    c: np.zeros((1,) + v.shape[1:], v.dtype)
                    for c, v in cols.items()
                }
                probe = fn(probe_cols)
                if probe is None:
                    # cache a sentinel so fn(None) doesn't re-probe forever
                    self._out_spec = (np.dtype(np.float64), ())
                else:
                    probe = np.asarray(probe)
                    self._out_spec = (probe.dtype, probe.shape[1:]
                                      if probe.ndim else ())
            dtype, trailing = self._out_spec
            return np.zeros((0,) + tuple(trailing), dtype)
        if not self.bucket:
            out = np.asarray(fn(cols))
        else:
            b = bucket_rows(rows)
            if b != rows:
                cols = {c: pad_rows(v, b) for c, v in cols.items()}
            out = np.asarray(fn(cols))[:rows]
        if out.ndim:
            self._out_spec = (out.dtype, out.shape[1:])
        return out


@dataclass
class Predicate:
    """UDF output -> boolean row mask, e.g. DogBreedClassifier(...) == 'great dane'."""

    name: str
    udf: UDF
    compare: Callable[[np.ndarray], np.ndarray]
    cacheable: bool = True

    @property
    def resource(self) -> str:
        return self.udf.resource

    def evaluate_outputs(self, data: Dict[str, np.ndarray]) -> np.ndarray:
        return self.udf(data)

    def mask_from_outputs(self, outputs: np.ndarray) -> np.ndarray:
        return np.asarray(self.compare(outputs), bool)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
