"""Runtime UDF statistics (§3.3) — collected DURING execution, never a-priori.

Per predicate: EMA cost per row, lottery-based selectivity (tickets =
rows routed, wins = rows dropped — the Eddy paper's estimator), cache hit
rate, queue length, and per-worker outstanding-work accounting for the
data-aware Laminar policy.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass
class Ema:
    alpha: float = 0.2
    value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (
            self.alpha * x + (1 - self.alpha) * self.value
        )
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


@dataclass
class PredicateStats:
    name: str
    cost_per_row: Ema = field(default_factory=lambda: Ema(0.3))
    tickets: int = 0          # rows routed (lottery tickets)
    wins: int = 0             # rows filtered out (lottery wins)
    cache_hits: int = 0
    cache_probes: int = 0
    batches: int = 0
    queue_len: int = 0
    busy_until: float = 0.0   # simulated-clock resource horizon
    # content-based routing [Bizarro et al., cited by the paper §2.2]:
    # per-content-bucket lottery counters
    bucket_tickets: Dict[int, int] = field(default_factory=dict)
    bucket_wins: Dict[int, int] = field(default_factory=dict)

    _lock: threading.Lock = field(default_factory=threading.Lock)

    # ------------------------- recording ------------------------- #
    def record_eval(self, rows_in: int, rows_out: int, seconds: float,
                    bucket: Optional[int] = None) -> None:
        with self._lock:
            self.batches += 1
            self.tickets += rows_in
            self.wins += rows_in - rows_out
            if rows_in > 0:
                self.cost_per_row.update(seconds / rows_in)
            if bucket is not None:
                self.bucket_tickets[bucket] = (
                    self.bucket_tickets.get(bucket, 0) + rows_in
                )
                self.bucket_wins[bucket] = (
                    self.bucket_wins.get(bucket, 0) + rows_in - rows_out
                )

    def record_cache(self, probes: int, hits: int) -> None:
        with self._lock:
            self.cache_probes += probes
            self.cache_hits += hits

    # ------------------------- estimates ------------------------- #
    @property
    def measured(self) -> bool:
        return self.batches > 0

    def cost(self, default: float = 1e-3) -> float:
        return self.cost_per_row.get(default)

    def selectivity(self, default: float = 0.5,
                    bucket: Optional[int] = None,
                    min_bucket_tickets: int = 20) -> float:
        """Fraction of rows that PASS (lottery estimator).

        With ``bucket`` given, uses the content-bucket-specific estimate
        once it has enough tickets, else falls back to the global one."""
        with self._lock:
            if bucket is not None:
                bt = self.bucket_tickets.get(bucket, 0)
                if bt >= min_bucket_tickets:
                    return 1.0 - self.bucket_wins.get(bucket, 0) / bt
            if self.tickets == 0:
                return default
            return 1.0 - self.wins / self.tickets

    def pressure(self, queue_depth: int) -> float:
        """Resource-arbitration pressure: measured cost/row x queue depth.

        The ResourceArbiter ranks slot claimants on this (§5.2): a
        predicate whose PROFILED cost is high and whose queues are deep is
        the current bottleneck and wins contended capacity. A drained
        predicate (depth 0) exerts no pressure regardless of cost."""
        return self.cost() * max(0, queue_depth)

    def cache_hit_rate(self) -> float:
        with self._lock:
            if self.cache_probes == 0:
                return 0.0
            return self.cache_hits / self.cache_probes

    def score(self, bucket: Optional[int] = None,
              resolution: Optional[float] = None) -> float:
        """Classic rank: cost / (1 - selectivity); lower runs first.

        ``resolution`` quantizes the selectivity estimate before scoring so
        rank keys tie at degenerate (noise-level-equal) statistics instead
        of flipping on estimator drift — the policies pass their rank
        resolution here to keep this formula the single source of truth."""
        sel = self.selectivity(bucket=bucket)
        if resolution:
            sel = round(sel / resolution) * resolution
        return self.cost() / max(1.0 - sel, 1e-6)

    def snapshot(self) -> Dict[str, float]:
        return {
            "cost_per_row": self.cost(),
            "selectivity": self.selectivity(),
            "score": self.score(),
            "cache_hit_rate": self.cache_hit_rate(),
            "batches": self.batches,
        }


class StatsBoard:
    """All predicate stats + per-worker load accounting (one per executor).

    ``cost_alpha`` sets the cost-estimator EMA horizon: small values model
    long-window averaging (the paper's Fig 9a estimator that "cannot
    promptly adjust" across cache-boundary segments)."""

    def __init__(self, predicate_names, *, cost_alpha: float = 0.3):
        self.cost_alpha = cost_alpha
        self.preds: Dict[str, PredicateStats] = {
            n: PredicateStats(n, cost_per_row=Ema(cost_alpha))
            for n in predicate_names
        }
        # Routing predicates declared at construction. Auxiliary entries
        # (per-kernel launch costs, fed by ``launch.connect_stats_board``)
        # are created lazily via ``ensure`` and never gate warmup.
        self._declared = frozenset(predicate_names)
        self.worker_load: Dict[str, float] = {}
        self.proxy_rate = Ema(0.3)  # seconds per proxy unit (data-aware ETA)
        self.bucket_fn = None       # content-based routing: batch -> bucket id
        self._lock = threading.Lock()

    def bucket_of(self, batch) -> Optional[int]:
        if self.bucket_fn is None:
            return None
        try:
            return int(self.bucket_fn(batch))
        except Exception:
            return None

    def note_proxy_rate(self, units: float, seconds: float) -> None:
        if units > 0:
            with self._lock:
                self.proxy_rate.update(seconds / units)

    def __getitem__(self, name: str) -> PredicateStats:
        return self.preds[name]

    def ensure(self, name: str) -> PredicateStats:
        """Get-or-create an entry, safely from any worker thread.

        Kernel launch hooks report under the kernel's own name, which is
        unknown until the first launch; entries appear mid-run while the
        eddy thread reads the board, so creation must hold the lock."""
        with self._lock:
            st = self.preds.get(name)
            if st is None:
                st = PredicateStats(name, cost_per_row=Ema(self.cost_alpha))
                self.preds[name] = st
            return st

    def ensure_kernel(self, name: str) -> PredicateStats:
        """Entry for a kernel-launch timing stream.

        If a DECLARED routing predicate already owns ``name`` (a predicate
        deliberately named after its kernel), the kernel entry is
        namespaced ``kernel:<name>`` — launch events are compute samples
        (rows_in == rows_out), so merging them into a predicate's entry
        would drag its lottery selectivity toward 1.0 and flip its warmup
        'measured' bit before any batch was routed."""
        if name in self._declared:
            name = "kernel:" + name
        return self.ensure(name)

    def all_measured(self) -> bool:
        """Warmup gate: every DECLARED routing predicate has a measurement.

        Lazily-created kernel entries are deliberately excluded — a kernel
        timing arriving mid-warmup must not wedge the router into waiting
        for a "predicate" it can never route a batch to."""
        with self._lock:
            return all(self.preds[n].measured for n in self._declared)

    # ---------------- data-aware load accounting ---------------- #
    def add_load(self, worker: str, units: float) -> None:
        with self._lock:
            self.worker_load[worker] = self.worker_load.get(worker, 0.0) + units

    def finish_load(self, worker: str, units: float) -> None:
        with self._lock:
            self.worker_load[worker] = max(
                0.0, self.worker_load.get(worker, 0.0) - units
            )

    def load_of(self, worker: str) -> float:
        with self._lock:
            return self.worker_load.get(worker, 0.0)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        with self._lock:  # copy first: entries may be created concurrently
            items = list(self.preds.items())
        return {n: p.snapshot() for n, p in items}
