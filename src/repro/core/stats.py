"""Runtime UDF statistics (§3.3) — collected DURING execution, never a-priori.

Per predicate: EMA cost per row, lottery-based selectivity (tickets =
rows routed, wins = rows dropped — the Eddy paper's estimator), cache hit
rate, queue length, and per-worker outstanding-work accounting for the
data-aware Laminar policy.

LOCK-SHARDED (``shards > 1``): each predicate's entry becomes a
``ShardedPredicateStats`` — one ``PredicateStats`` stripe per routing
shard. Writers (worker threads recording eval timings, kernel launch
hooks) record into a THREAD-AFFINE stripe, so concurrent recorders on
different threads never contend on one lock; readers (the shards' routing
policies) fold the stripes into a merged estimate (tickets/wins summed,
cost = batch-weighted mean of the stripe EMAs). ``shards=1`` (the default,
and always the case under SimClock) keeps the original single-entry
behavior bit-for-bit.

LAUNCH-COST DECOMPOSITION (micro-batch coalescing, GRACEFUL-style): each
entry additionally keeps EMA moments of per-LAUNCH ``(computed_rows,
seconds)`` samples and fits ``seconds ~= fixed + marginal * rows`` online
(one-variable least squares over the EMA moments).  ``launch_overhead()``
exposes the fitted fixed term and ``marginal_cost()`` the per-row slope —
the evidence the adaptive CoalescePlanner (core/coalesce.py) uses to pick
the row count where launch amortization flattens.  Samples are recorded
against COMPUTED rows (cache hits excluded): the decomposition models the
kernel launch, not the probe.  ``record_fused_eval`` records one fused
launch while crediting tickets/wins per original segment, so the lottery
selectivity estimator sees exactly the per-batch history the uncoalesced
path would have produced.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

# Launch-decomposition fit gates: at least this many per-launch samples,
# with row-count variance above the (relative) floor — a single repeated
# batch size cannot identify a slope, so the fit stays None until fused
# or heterogeneous launches provide spread.
LAUNCH_FIT_MIN_SAMPLES = 4
LAUNCH_FIT_MIN_REL_VAR = 1e-6


@dataclass
class Ema:
    alpha: float = 0.2
    value: Optional[float] = None

    def update(self, x: float) -> float:
        self.value = x if self.value is None else (
            self.alpha * x + (1 - self.alpha) * self.value
        )
        return self.value

    def get(self, default: float = 0.0) -> float:
        return default if self.value is None else self.value


@dataclass
class PredicateStats:
    name: str
    cost_per_row: Ema = field(default_factory=lambda: Ema(0.3))
    tickets: int = 0          # rows routed (lottery tickets)
    wins: int = 0             # rows filtered out (lottery wins)
    cache_hits: int = 0
    cache_probes: int = 0
    batches: int = 0
    queue_len: int = 0
    busy_until: float = 0.0   # simulated-clock resource horizon
    # content-based routing [Bizarro et al., cited by the paper §2.2]:
    # per-content-bucket lottery counters
    bucket_tickets: Dict[int, int] = field(default_factory=dict)
    bucket_wins: Dict[int, int] = field(default_factory=dict)

    # coalescing observability: launches counts kernel-launch-level samples
    # (a fused launch counts ONCE); fused_* count only launches that fused
    # >= 2 batches and the original batches they covered
    launches: int = 0
    fused_launches: int = 0
    fused_batches: int = 0
    coalesced_rows: int = 0

    # launch-cost decomposition moments: EMAs of rows, seconds, rows^2 and
    # rows*seconds over per-launch samples (see module docstring)
    lc_rows: Ema = field(default_factory=lambda: Ema(0.2))
    lc_secs: Ema = field(default_factory=lambda: Ema(0.2))
    lc_rows2: Ema = field(default_factory=lambda: Ema(0.2))
    lc_rowsecs: Ema = field(default_factory=lambda: Ema(0.2))

    _lock: threading.Lock = field(default_factory=threading.Lock)

    # ------------------------- recording ------------------------- #
    def _note_launch_locked(self, computed_rows: int, seconds: float) -> None:
        """One per-launch decomposition sample (caller holds the lock).

        ``computed_rows == 0`` means no kernel ran (full cache hit): there
        is no launch to decompose, so the sample is skipped."""
        if computed_rows <= 0:
            return
        self.launches += 1
        r = float(computed_rows)
        self.lc_rows.update(r)
        self.lc_secs.update(seconds)
        self.lc_rows2.update(r * r)
        self.lc_rowsecs.update(r * seconds)

    def record_eval(self, rows_in: int, rows_out: int, seconds: float,
                    bucket: Optional[int] = None,
                    computed_rows: Optional[int] = None) -> None:
        """One uncoalesced evaluation. ``computed_rows`` (defaulting to
        ``rows_in``) is the number of rows the launch actually computed —
        cache hits excluded — and feeds the launch-cost decomposition."""
        with self._lock:
            self.batches += 1
            self.tickets += rows_in
            self.wins += rows_in - rows_out
            if rows_in > 0:
                self.cost_per_row.update(seconds / rows_in)
            self._note_launch_locked(
                rows_in if computed_rows is None else computed_rows, seconds
            )
            if bucket is not None:
                self.bucket_tickets[bucket] = (
                    self.bucket_tickets.get(bucket, 0) + rows_in
                )
                self.bucket_wins[bucket] = (
                    self.bucket_wins.get(bucket, 0) + rows_in - rows_out
                )

    def record_fused_eval(
        self,
        segments: Sequence[Tuple[int, int, Optional[int]]],
        seconds: float,
        computed_rows: Optional[int] = None,
    ) -> None:
        """One FUSED launch covering ``segments`` of original batches.

        ``segments`` is ``[(rows_in, rows_out, bucket), ...]`` per original
        batch: tickets/wins (global and per content bucket) are credited
        per segment — identical to what per-batch ``record_eval`` calls
        would have accumulated — while the cost EMA and the decomposition
        see ONE launch over the summed rows, so fusing never drags
        ``cost_per_row`` up by charging the full fused launch to each
        small batch."""
        with self._lock:
            total_in = sum(s[0] for s in segments)
            total_out = sum(s[1] for s in segments)
            self.batches += len(segments)
            self.tickets += total_in
            self.wins += total_in - total_out
            if total_in > 0:
                self.cost_per_row.update(seconds / total_in)
            self._note_launch_locked(
                total_in if computed_rows is None else computed_rows, seconds
            )
            if len(segments) > 1:
                self.fused_launches += 1
                self.fused_batches += len(segments)
                self.coalesced_rows += total_in
            for rows_in, rows_out, bucket in segments:
                if bucket is not None:
                    self.bucket_tickets[bucket] = (
                        self.bucket_tickets.get(bucket, 0) + rows_in
                    )
                    self.bucket_wins[bucket] = (
                        self.bucket_wins.get(bucket, 0) + rows_in - rows_out
                    )

    def record_cache(self, probes: int, hits: int) -> None:
        with self._lock:
            self.cache_probes += probes
            self.cache_hits += hits

    # ------------------------- estimates ------------------------- #
    @property
    def measured(self) -> bool:
        return self.batches > 0

    def cost(self, default: float = 1e-3) -> float:
        return self.cost_per_row.get(default)

    def selectivity(self, default: float = 0.5,
                    bucket: Optional[int] = None,
                    min_bucket_tickets: int = 20) -> float:
        """Fraction of rows that PASS (lottery estimator).

        With ``bucket`` given, uses the content-bucket-specific estimate
        once it has enough tickets, else falls back to the global one."""
        with self._lock:
            if bucket is not None:
                bt = self.bucket_tickets.get(bucket, 0)
                if bt >= min_bucket_tickets:
                    return 1.0 - self.bucket_wins.get(bucket, 0) / bt
            if self.tickets == 0:
                return default
            return 1.0 - self.wins / self.tickets

    def pressure(self, queue_depth: int) -> float:
        """Resource-arbitration pressure: measured cost/row x queue depth.

        The ResourceArbiter ranks slot claimants on this (§5.2): a
        predicate whose PROFILED cost is high and whose queues are deep is
        the current bottleneck and wins contended capacity. A drained
        predicate (depth 0) exerts no pressure regardless of cost."""
        return self.cost() * max(0, queue_depth)

    def cache_hit_rate(self) -> float:
        with self._lock:
            if self.cache_probes == 0:
                return 0.0
            return self.cache_hits / self.cache_probes

    def launch_decomposition(
        self, min_samples: int = LAUNCH_FIT_MIN_SAMPLES,
    ) -> Optional[Tuple[float, float]]:
        """Fitted ``(fixed_seconds, marginal_seconds_per_row)`` or None.

        One-variable least squares over the EMA moments of per-launch
        ``(rows, seconds)`` samples: ``marginal = cov(r, s) / var(r)``,
        ``fixed = mean(s) - marginal * mean(r)``.  Returns None until
        ``min_samples`` launches landed AND the observed row counts have
        enough spread to identify a slope (all-identical batch sizes
        cannot); both terms are clamped non-negative — estimator noise can
        produce a slightly negative intercept, which would otherwise make
        the planner chase negative overhead."""
        with self._lock:
            if self.launches < min_samples:
                return None
            r, s = self.lc_rows.get(), self.lc_secs.get()
            var = self.lc_rows2.get() - r * r
            if var <= LAUNCH_FIT_MIN_REL_VAR * max(r * r, 1.0):
                return None
            marginal = (self.lc_rowsecs.get() - r * s) / var
            fixed = s - marginal * r
            return max(fixed, 0.0), max(marginal, 0.0)

    def score(self, bucket: Optional[int] = None,
              resolution: Optional[float] = None) -> float:
        """Classic rank: cost / (1 - selectivity); lower runs first.

        ``resolution`` quantizes the selectivity estimate before scoring so
        rank keys tie at degenerate (noise-level-equal) statistics instead
        of flipping on estimator drift — the policies pass their rank
        resolution here to keep this formula the single source of truth."""
        sel = self.selectivity(bucket=bucket)
        if resolution:
            sel = round(sel / resolution) * resolution
        return self.cost() / max(1.0 - sel, 1e-6)

    def snapshot(self) -> Dict[str, float]:
        return {
            "cost_per_row": self.cost(),
            "selectivity": self.selectivity(),
            "score": self.score(),
            "cache_hit_rate": self.cache_hit_rate(),
            "batches": self.batches,
            "launches": self.launches,
            "fused_launches": self.fused_launches,
            "fused_batches": self.fused_batches,
        }


class ShardedPredicateStats:
    """Lock-sharded predicate statistics: one ``PredicateStats`` stripe per
    routing shard, merged on read.

    Writes go to a THREAD-AFFINE stripe (``thread id % shards``): each
    recording thread owns one stripe's lock, so N workers + N shards never
    serialize on a single per-predicate lock. Reads fold across stripes —
    counter sums for the lottery estimator, a batch-weighted mean of the
    stripe EMAs for cost — without any global lock (counter reads are
    GIL-atomic; a fold may see a stripe mid-update, which perturbs the
    estimate by at most one batch, well under estimator noise)."""

    def __init__(self, name: str, stripes):
        self.name = name
        self.stripes = list(stripes)

    def _stripe(self) -> PredicateStats:
        return self.stripes[threading.get_ident() % len(self.stripes)]

    def stripe(self, i: int) -> PredicateStats:
        """Direct stripe access (tests / per-shard observability)."""
        return self.stripes[i % len(self.stripes)]

    # ------------------------- recording ------------------------- #
    def record_eval(self, rows_in: int, rows_out: int, seconds: float,
                    bucket: Optional[int] = None,
                    computed_rows: Optional[int] = None) -> None:
        self._stripe().record_eval(rows_in, rows_out, seconds, bucket=bucket,
                                   computed_rows=computed_rows)

    def record_fused_eval(
        self,
        segments: Sequence[Tuple[int, int, Optional[int]]],
        seconds: float,
        computed_rows: Optional[int] = None,
    ) -> None:
        self._stripe().record_fused_eval(segments, seconds,
                                         computed_rows=computed_rows)

    def record_cache(self, probes: int, hits: int) -> None:
        self._stripe().record_cache(probes, hits)

    # ------------------------- merged estimates ------------------------- #
    @property
    def measured(self) -> bool:
        return any(s.measured for s in self.stripes)

    @property
    def batches(self) -> int:
        return sum(s.batches for s in self.stripes)

    @property
    def tickets(self) -> int:
        return sum(s.tickets for s in self.stripes)

    @property
    def wins(self) -> int:
        return sum(s.wins for s in self.stripes)

    @property
    def launches(self) -> int:
        return sum(s.launches for s in self.stripes)

    @property
    def fused_launches(self) -> int:
        return sum(s.fused_launches for s in self.stripes)

    @property
    def fused_batches(self) -> int:
        return sum(s.fused_batches for s in self.stripes)

    @property
    def coalesced_rows(self) -> int:
        return sum(s.coalesced_rows for s in self.stripes)

    def launch_decomposition(
        self, min_samples: int = LAUNCH_FIT_MIN_SAMPLES,
    ) -> Optional[Tuple[float, float]]:
        """Launch-weighted fold of the per-stripe moment EMAs, fitted once.

        Folding the MOMENTS (not the per-stripe fits) keeps a stripe with
        too little spread from vetoing the merged estimate: the variance
        that identifies the slope may only exist ACROSS stripes."""
        num_r = num_s = num_r2 = num_rs = den = 0.0
        total = 0
        for s in self.stripes:
            with s._lock:
                if s.launches == 0:
                    continue
                w = s.launches
                total += w
                num_r += s.lc_rows.get() * w
                num_s += s.lc_secs.get() * w
                num_r2 += s.lc_rows2.get() * w
                num_rs += s.lc_rowsecs.get() * w
                den += w
        if total < min_samples or den == 0:
            return None
        r, sec = num_r / den, num_s / den
        var = num_r2 / den - r * r
        if var <= LAUNCH_FIT_MIN_REL_VAR * max(r * r, 1.0):
            return None
        marginal = (num_rs / den - r * sec) / var
        fixed = sec - marginal * r
        return max(fixed, 0.0), max(marginal, 0.0)

    def cost(self, default: float = 1e-3) -> float:
        num = den = 0.0
        for s in self.stripes:
            v = s.cost_per_row.value
            if v is not None:
                w = max(s.batches, 1)
                num += v * w
                den += w
        return num / den if den else default

    def selectivity(self, default: float = 0.5,
                    bucket: Optional[int] = None,
                    min_bucket_tickets: int = 20) -> float:
        if bucket is not None:
            bt = sum(s.bucket_tickets.get(bucket, 0) for s in self.stripes)
            if bt >= min_bucket_tickets:
                bw = sum(s.bucket_wins.get(bucket, 0) for s in self.stripes)
                return 1.0 - bw / bt
        tickets = self.tickets
        if tickets == 0:
            return default
        return 1.0 - self.wins / tickets

    def pressure(self, queue_depth: int) -> float:
        return self.cost() * max(0, queue_depth)

    def cache_hit_rate(self) -> float:
        probes = sum(s.cache_probes for s in self.stripes)
        if probes == 0:
            return 0.0
        return sum(s.cache_hits for s in self.stripes) / probes

    def score(self, bucket: Optional[int] = None,
              resolution: Optional[float] = None) -> float:
        sel = self.selectivity(bucket=bucket)
        if resolution:
            sel = round(sel / resolution) * resolution
        return self.cost() / max(1.0 - sel, 1e-6)

    def snapshot(self) -> Dict[str, float]:
        return {
            "cost_per_row": self.cost(),
            "selectivity": self.selectivity(),
            "score": self.score(),
            "cache_hit_rate": self.cache_hit_rate(),
            "batches": self.batches,
            "launches": self.launches,
            "fused_launches": self.fused_launches,
            "fused_batches": self.fused_batches,
        }


class StatsBoard:
    """All predicate stats + per-worker load accounting (one per executor).

    ``cost_alpha`` sets the cost-estimator EMA horizon: small values model
    long-window averaging (the paper's Fig 9a estimator that "cannot
    promptly adjust" across cache-boundary segments).

    ``shards`` lock-shards every entry (see ``ShardedPredicateStats``) for
    the N-shard routing core; the worker-load ledger's lock is striped by
    worker id so concurrent ``LaminarRouter.submit`` calls from different
    shards don't serialize on one lock either."""

    def __init__(self, predicate_names, *, cost_alpha: float = 0.3,
                 shards: int = 1):
        self.cost_alpha = cost_alpha
        self.shards = max(1, shards)
        self.preds: Dict[str, PredicateStats] = {
            n: self._new_entry(n) for n in predicate_names
        }
        # Routing predicates declared at construction. Auxiliary entries
        # (per-kernel launch costs, fed by ``launch.connect_stats_board``)
        # are created lazily via ``ensure`` and never gate warmup.
        self._declared = frozenset(predicate_names)
        self.worker_load: Dict[str, float] = {}
        self.proxy_rate = Ema(0.3)  # seconds per proxy unit (data-aware ETA)
        self.bucket_fn = None       # content-based routing: batch -> bucket id
        # failure-aware routing: the executor attaches its FaultLedger
        # (core/faults.py) here; policies fold ``fault_penalty`` into
        # their rank keys. None (or a clean ledger) => penalty exactly
        # 1.0, so fault-free rank keys are bit-identical.
        self.faults = None
        self._lock = threading.Lock()
        self._load_locks = [threading.Lock() for _ in range(self.shards)]

    def _new_entry(self, name: str):
        if self.shards == 1:
            return PredicateStats(name, cost_per_row=Ema(self.cost_alpha))
        return ShardedPredicateStats(name, [
            PredicateStats(name, cost_per_row=Ema(self.cost_alpha))
            for _ in range(self.shards)
        ])

    def _load_lock(self, worker: str) -> threading.Lock:
        return self._load_locks[hash(worker) % len(self._load_locks)]

    def bucket_of(self, batch) -> Optional[int]:
        if self.bucket_fn is None:
            return None
        try:
            return int(self.bucket_fn(batch))
        except Exception:
            return None

    def fault_penalty(self, name: str) -> float:
        """Routing rank multiplier from the attached FaultLedger: exactly
        1.0 for a healthy predicate, growing in the error-rate EMA for a
        flaky one (see core/faults.FaultLedger.rank_penalty)."""
        f = self.faults
        return 1.0 if f is None else f.rank_penalty(name)

    def note_proxy_rate(self, units: float, seconds: float) -> None:
        if units > 0:
            with self._lock:
                self.proxy_rate.update(seconds / units)

    def __getitem__(self, name: str) -> PredicateStats:
        return self.preds[name]

    def ensure(self, name: str, shard: Optional[int] = None):
        """Get-or-create an entry, safely from any worker thread.

        Kernel launch hooks report under the kernel's own name, which is
        unknown until the first launch; entries appear mid-run while the
        eddy shards read the board, so creation must hold the lock.

        Shard-aware: with ``shard`` given on a sharded board, returns that
        shard's write stripe directly (an uncontended recording target);
        otherwise returns the merged entry (whose recorders pick a
        thread-affine stripe themselves)."""
        with self._lock:
            st = self.preds.get(name)
            if st is None:
                st = self._new_entry(name)
                self.preds[name] = st
        if shard is not None and isinstance(st, ShardedPredicateStats):
            return st.stripe(shard)
        return st

    def seed_prior(self, name: str, *, cost_per_row: Optional[float] = None,
                   selectivity: Optional[float] = None,
                   tickets: int = 0):
        """Warm-start an entry from a persistent statistics store.

        Seeds the cost EMA and plants ``tickets`` pseudo-tickets at the
        given selectivity (wins derived), then marks the entry measured
        (``batches >= 1``) so the warmup circulation does not re-profile a
        predicate another query already profiled. Pseudo-tickets bound the
        seed's vote against fresh observations: the lottery estimator
        folds real rows straight in, so a run that disagrees with the seed
        out-votes it after ~``tickets`` routed rows. On a sharded board
        the seed lands on stripe 0 and merged reads fold it exactly like
        any other stripe's history. Call BEFORE the run starts — seeding
        overwrites the cost EMA's current value."""
        st = self.ensure(name)
        target = st.stripe(0) if isinstance(st, ShardedPredicateStats) else st
        with target._lock:
            if cost_per_row is not None:
                target.cost_per_row.value = float(cost_per_row)
            if selectivity is not None and tickets > 0:
                sel = min(max(float(selectivity), 0.0), 1.0)
                target.tickets += int(tickets)
                target.wins += int(round(tickets * (1.0 - sel)))
            target.batches = max(target.batches, 1)
        return st

    def ensure_kernel(self, name: str) -> PredicateStats:
        """Entry for a kernel-launch timing stream.

        If a DECLARED routing predicate already owns ``name`` (a predicate
        deliberately named after its kernel), the kernel entry is
        namespaced ``kernel:<name>`` — launch events are compute samples
        (rows_in == rows_out), so merging them into a predicate's entry
        would drag its lottery selectivity toward 1.0 and flip its warmup
        'measured' bit before any batch was routed."""
        if name in self._declared:
            name = "kernel:" + name
        return self.ensure(name)

    def batch_counts(self) -> Dict[str, int]:
        """Merged per-predicate batch counts (declared predicates only).

        The live-fold bookkeeping the multi-tenant service reads: paired
        with ``StatsStore.record_live`` it tells how much NEW evidence a
        running executor has produced since the last cross-query fold."""
        with self._lock:
            items = list(self.preds.items())
        return {name: st.batches for name, st in items}

    def all_measured(self, exclude: Sequence[str] = ()) -> bool:
        """Warmup gate: every DECLARED routing predicate has a measurement.

        Lazily-created kernel entries are deliberately excluded — a kernel
        timing arriving mid-warmup must not wedge the router into waiting
        for a "predicate" it can never route a batch to.  ``exclude``
        names predicates exempt from the gate: a QUARANTINED predicate
        (core/faults.py) may never produce a measurement, and waiting for
        one would circulate warmup batches forever."""
        with self._lock:
            return all(
                self.preds[n].measured for n in self._declared
                if n not in exclude
            )

    # ---------------- data-aware load accounting ---------------- #
    # The ledger lock is striped by worker id: submits racing from
    # different shards only contend when they touch the same worker.
    def add_load(self, worker: str, units: float) -> None:
        with self._load_lock(worker):
            self.worker_load[worker] = self.worker_load.get(worker, 0.0) + units

    def finish_load(self, worker: str, units: float) -> None:
        with self._load_lock(worker):
            self.worker_load[worker] = max(
                0.0, self.worker_load.get(worker, 0.0) - units
            )

    def load_of(self, worker: str) -> float:
        with self._load_lock(worker):
            return self.worker_load.get(worker, 0.0)

    def snapshot(self, shard: Optional[int] = None) -> Dict[str, Dict[str, float]]:
        """Per-predicate snapshots — merged by default; ``shard=i`` returns
        shard ``i``'s un-merged stripe view on a sharded board (per-shard
        observability; identical to the merged view when ``shards == 1``)."""
        with self._lock:  # copy first: entries may be created concurrently
            items = list(self.preds.items())
        if shard is not None:
            return {
                n: (p.stripe(shard) if isinstance(p, ShardedPredicateStats)
                    else p).snapshot()
                for n, p in items
            }
        return {n: p.snapshot() for n, p in items}
