"""Deterministic simulated clock for scheduling experiments.

The paper's scheduling claims (Fig. 4: cost-driven = 14 time units vs
score-driven = 20; Fig. 7 sweeps; Fig. 11 scaling) depend on concurrency
that a 1-core CPU container cannot physically exhibit. The routing logic in
this repo is clock-agnostic: executors take a ``Clock``, and ``SimClock``
advances virtual time per (worker, batch) from the predicates' cost models —
making the paper's timelines exactly reproducible and assertable in tests.
``WallClock`` is the production clock.

MICRO-BATCH COALESCING under SimClock: a fused launch is ONE
``occupy_shared`` call — ``ready`` is the fused batch's ``sim_ready``
(the max over its constituents, i.e. the last arrival) and ``cost`` is
the cost model evaluated once over the summed computed rows, so an affine
model pays one fixed launch term plus the summed per-row terms.  Every
split output inherits the single fused finish as its ``sim_ready``.  The
deterministic suites keep coalescing OFF (executor default): their pinned
timelines assume one launch per batch.
"""
from __future__ import annotations

import heapq
import threading
import time
from dataclasses import dataclass, field
from typing import Dict


class WallClock:
    simulated = False

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        time.sleep(dt)


@dataclass
class SimClock:
    """Virtual time with per-resource busy tracking.

    Workers call ``occupy(resource, cost)``: the batch completes at
    ``max(now, resource_free) + cost``; the resource's free-time advances.
    Concurrency across resources is exact and deterministic.
    """

    simulated = True
    _now: float = 0.0
    _free: Dict[str, float] = field(default_factory=dict)
    _busy: Dict[str, float] = field(default_factory=dict)  # cumulative occupancy
    _lock: threading.Lock = field(default_factory=threading.Lock)

    def now(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, dt: float) -> None:
        with self._lock:
            self._now += dt

    def occupy(self, resource: str, cost: float, *, ready: float = None) -> float:
        """Schedule ``cost`` seconds of work on ``resource``; returns finish time."""
        with self._lock:
            start = max(self._now if ready is None else ready, self._free.get(resource, 0.0))
            finish = start + cost
            self._free[resource] = finish
            self._now = max(self._now, finish)
            return finish

    def occupy_shared(self, worker: str, device: str, cost: float,
                      serial_fraction: float = 0.0, ready: float = 0.0) -> float:
        """Worker-local cost with a ``serial_fraction`` contending on the
        shared device resource — models spatial multiplexing saturation
        (paper §5.1: overlap of data movement / CPU / device compute).

        ``ready`` is the batch's virtual arrival time: starts are
        max(ready, resource_free) — NOT the global clock — so the virtual
        timeline is a proper discrete-event simulation, independent of the
        real thread interleaving.
        """
        with self._lock:
            start = max(ready, self._free.get(worker, 0.0),
                        self._free.get(device, 0.0))
            finish = start + cost
            self._free[worker] = finish
            self._free[device] = start + cost * serial_fraction
            self._busy[worker] = self._busy.get(worker, 0.0) + cost
            self._busy[device] = self._busy.get(device, 0.0) + cost * serial_fraction
            self._now = max(self._now, finish)
            return finish

    def resource_busy_until(self, resource: str) -> float:
        with self._lock:
            return self._free.get(resource, 0.0)

    def release_horizon(self, resource: str) -> float:
        """Detach and return a retiring lease's busy horizon (§5.2).

        The outstanding virtual work now belongs to the released SLOT, not
        the retired worker: clearing the worker's entry means a later
        re-lease of the same context starts from the slot's inherited
        horizon — never from a stale copy of work that was already handed
        off (which would double-count it)."""
        with self._lock:
            return self._free.pop(resource, 0.0)

    def seed_horizon(self, resource: str, until: float) -> None:
        """Seed a new lease holder with its slot's inherited busy horizon.

        The slot models one physical execution context, so the new lease
        cannot start before the previous holder's outstanding virtual work
        drains — this is what keeps the deterministic Fig. 7 / UC3
        timelines exact across cross-predicate reallocation. The value is
        carried on the Slot itself (recorded by ``release_horizon``), so
        the transfer also works when two executors with separate SimClocks
        share one DevicePool."""
        with self._lock:
            if until > self._free.get(resource, 0.0):
                self._free[resource] = until

    def lease_handoff(self, frm: str, to: str) -> None:
        """Same-clock convenience: MOVE ``frm``'s horizon onto ``to``."""
        self.seed_horizon(to, self.release_horizon(frm))

    def busy_time(self, resource: str) -> float:
        """Cumulative occupied seconds (utilization numerator, Fig 12)."""
        with self._lock:
            return self._busy.get(resource, 0.0)

    @property
    def makespan(self) -> float:
        # _now tracks the max finish ever scheduled, so the makespan
        # survives released leases detaching their _free entries
        with self._lock:
            return max([self._now, *self._free.values()])
