"""TPU-native on-device short-circuit filtering (beyond-paper, DESIGN.md §2).

Host-side Eddy routing costs ~100us/decision — fine for 10-row video batches
wrapping 30ms UDFs, but unusable inside a serving step that evaluates
thousands of rows. This module fuses the SAME short-circuit semantics into a
single jitted program:

  evaluate cheapest predicate on the full batch
  -> compact the survivors to a static bucket (sort-by-mask: dense compute)
  -> evaluate the next predicate on the compacted bucket only
  -> scatter the verdicts back.

Compaction buckets are static shapes (a size ladder) so one executable
serves any selectivity; the ladder level is picked with ``lax.cond`` on the
measured survivor count. This is "eager materialization" (§3.3) expressed
as dense TPU compute.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp


def compact_indices(mask: jax.Array, bucket: int) -> jax.Array:
    """Indices of True entries, padded (with n, an OOB sentinel) to bucket."""
    n = mask.shape[0]
    idx = jnp.where(mask, jnp.arange(n), n)
    return jnp.sort(idx)[:bucket]


def two_stage_filter(
    cheap_fn: Callable[[jax.Array], jax.Array],
    expensive_fn: Callable[[jax.Array], jax.Array],
    x: jax.Array,
    *,
    bucket_fraction: float = 0.5,
) -> jax.Array:
    """AND of two predicates with the expensive one on compacted buckets.

    x: (N, ...) rows -> (N,) bool, EXACT for any selectivity: a while_loop
    keeps evaluating bucket-sized compactions of the not-yet-covered
    survivors until none remain. The expensive fn is traced ONCE at bucket
    shape; runtime cost is ceil(survivors / bucket) bucket passes.
    """
    n = x.shape[0]
    bucket = max(1, int(n * bucket_fraction))
    cheap = cheap_fn(x).astype(bool)                      # (N,)
    cheapp = jnp.concatenate([cheap, jnp.zeros((1,), bool)])  # sentinel False
    xpad = jnp.concatenate([x, jnp.zeros_like(x[:1])], axis=0)

    def cond(state):
        _out, covered = state
        return jnp.any(cheapp & ~covered)

    def body(state):
        out, covered = state
        rest = (cheapp & ~covered)[:n]
        idx = compact_indices(rest, bucket)               # (bucket,) w/ sentinel n
        sub = xpad[idx]
        verdict = expensive_fn(sub).astype(bool)
        out = out.at[idx].set(verdict)                    # sentinel lands on slot n
        covered = covered.at[idx].set(True)
        return out, covered

    out0 = jnp.zeros((n + 1,), bool)
    cov0 = jnp.zeros((n + 1,), bool).at[n].set(True)
    out, _ = jax.lax.while_loop(cond, body, (out0, cov0))
    return cheap & out[:n]


def cascade_filter(
    fns_cheap_to_expensive: Sequence[Callable[[jax.Array], jax.Array]],
    x: jax.Array,
    *,
    bucket_fractions: Sequence[float] | None = None,
) -> jax.Array:
    """N-stage cascade: each stage sees only the survivors of the previous.

    Exact (falls back to full evaluation per stage when survivors exceed the
    bucket), dense, one executable. Stage order should be cheap->expensive —
    at serve time the caller orders by the Eddy StatsBoard costs, making this
    the jitted twin of cost-driven routing.
    """
    fns = list(fns_cheap_to_expensive)
    n = x.shape[0]
    if bucket_fractions is None:
        bucket_fractions = [0.5] * (len(fns) - 1)
    mask = fns[0](x).astype(bool)
    for fn, frac in zip(fns[1:], bucket_fractions):
        mask = mask & two_stage_filter(lambda _: mask, fn, x, bucket_fraction=frac)
    return mask
