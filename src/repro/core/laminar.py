"""Laminar router (§5): per-predicate elastic parallelism with GACU.

Greedy-allocation-conservative-use: ``max_workers`` contexts per predicate
are created up front (cheap — no compilation, no device buffers) and owned
by the ResourceArbiter; a worker only initializes when the router first
routes a batch to it. Capacity is LEASED, not owned: the router claims a
device slot from the arbiter whenever every active worker's input queue is
saturated (the utilization proxy: queue backpressure == device-idle
opportunity) up to the configured ceiling — "spawning through routing", no
pipeline surgery mid-query.

Scale-DOWN (§5.2): a worker whose queue has been idle past the drain
threshold offers to retire (``_on_worker_idle``); the router accepts when
it holds more than its one-worker floor, returning the slot to the
DevicePool so ANOTHER predicate's router can claim it — cross-predicate
reallocation, the paper's "dynamically allocates resources for evaluating
predicates". By default each executor gets a private unbounded pool, which
reproduces the pre-arbiter behavior exactly; contended deployments share a
bounded pool (see benchmarks/bench_uc2_realloc.py).

Device placement: workers are assigned to device groups round-robin at
construction; the DeviceAlternating policy keeps consecutive batches on
alternating devices (the paper's GPU-aware load balancing when scaling out).
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.coalesce import CoalesceConfig, CoalescePlanner
from repro.core.policies import LaminarPolicy, RoundRobin
from repro.core.queues import BoundedQueue, CentralQueue
from repro.core.resources import DRAIN_THRESHOLD_S, ResourceArbiter
from repro.core.simclock import SimClock
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.core.worker import WorkerContext

GACU_MAX_WORKERS = 50  # paper's hardcoded per-device ceiling

# back-off while the floor lease is denied (shared pool fully claimed by
# higher-pressure predicates); the submit loop retries until granted, and
# raises after the deadline — a predicate that cannot hold even one worker
# can never finish the query, and a loud error beats a silent hang
_FLOOR_RETRY_SLEEP_S = 0.01
FLOOR_STARVATION_DEADLINE_S = 10.0

# Wall poll interval for the VIRTUAL-idle drain path (``virtual_drain=``):
# under SimClock the retire *decision* reads only virtual state (the
# router's observed virtual frontier vs the worker's busy horizon), so the
# wall-clock poll cadence cannot change WHICH workers retire — only how
# promptly the deterministic verdict is acted on.
_VIRTUAL_DRAIN_POLL_S = 0.02


class LaminarRouter:
    def __init__(
        self,
        pred: Predicate,
        central: CentralQueue,
        stats: StatsBoard,
        *,
        cache: Optional[ReuseCache] = None,
        clock=None,
        policy: Optional[LaminarPolicy] = None,
        max_workers: int = GACU_MAX_WORKERS,
        devices: Sequence[str] = ("cpu",),
        serial_fraction: float = 0.0,
        on_error=None,
        arbiter: Optional[ResourceArbiter] = None,
        drain_threshold: Optional[float] = DRAIN_THRESHOLD_S,
        launch_token=None,
        coalesce: Optional[CoalesceConfig] = None,
        worker_queue_capacity: int = 2,
        fault_plan=None,
        fault_ledger=None,
        fault_config=None,
        watchdog=None,
        tracker=None,
        virtual_drain: bool = False,
        query: Optional[str] = None,
    ):
        self.pred = pred
        self.stats = stats
        self.policy = policy or RoundRobin()
        self.clock = clock
        self.max_workers = max(1, max_workers)
        self.arbiter = arbiter or ResourceArbiter()
        self.retirements = 0
        # One planner per predicate, SHARED by all its workers: the fused
        # launches any worker records refine the decomposition every other
        # worker's fuse target reads. None == the pre-coalescing loop.
        self.coalesce_planner = (
            CoalescePlanner(pred, stats[pred.name], coalesce,
                            wall_clock=not isinstance(clock, SimClock))
            if coalesce is not None else None
        )
        self._worker_queue_capacity = max(1, worker_queue_capacity)
        self._virtual_drain = bool(virtual_drain) and isinstance(clock, SimClock)
        idle_timeout = drain_threshold
        if isinstance(clock, SimClock):
            if self._virtual_drain and drain_threshold is not None:
                # virtual-idle drain: the threshold is measured in VIRTUAL
                # seconds of horizon idleness (_on_worker_idle reads the
                # SimClock, never the wall), so deterministic benchmarks
                # exercise scale-down too; the worker's wall idle_timeout
                # becomes just a poll cadence for the virtual verdict
                idle_timeout = _VIRTUAL_DRAIN_POLL_S
            else:
                # wall-clock queue idleness is meaningless in virtual time
                # and would make the deterministic timelines depend on real
                # thread scheduling: scale-down stays off under SimClock
                # unless virtual_drain= opts in
                drain_threshold = None
                idle_timeout = None
        self._drain_threshold = drain_threshold
        self._sim_frontier = 0.0  # latest virtual arrival seen by submit
        self._lock = threading.RLock()
        self._active: List[WorkerContext] = []

        def _factory(i: int) -> WorkerContext:
            return WorkerContext(
                wid=f"{pred.name}#{i}",
                index=i,
                pred=pred,
                central=central,
                stats=stats,
                cache=cache,
                clock=clock,
                device_group=devices[i % len(devices)],
                serial_fraction=serial_fraction,
                on_error=on_error,
                idle_timeout=idle_timeout,
                on_idle=self._on_worker_idle,
                launch_token=launch_token,
                coalesce=self.coalesce_planner,
                queue=BoundedQueue(self._worker_queue_capacity),
                fault_plan=fault_plan,
                ledger=fault_ledger,
                fault_config=fault_config,
                watchdog=watchdog,
                tracker=tracker,
            )

        # GREEDY allocation of worker contexts (lazy until first batch),
        # owned by the arbiter while registered; the router keeps its own
        # reference for inspection so a long-lived shared arbiter does not
        # accumulate dead executors' contexts after unregister(). The
        # floor slot is leased lazily on the first submit — a constructed
        # but never-run executor must not hold shared-pool capacity.
        self._contexts = self.arbiter.register(
            pred.name, num_workers=self.max_workers,
            factory=_factory, stats=stats, clock=clock, query=query,
        )

    # ------------------------------------------------------------------ #
    @property
    def workers(self) -> List[WorkerContext]:
        """All greedily-allocated contexts (leased or not)."""
        return list(self._contexts)

    @property
    def active_workers(self) -> List[WorkerContext]:
        with self._lock:
            return list(self._active)

    def _insert(self, w: WorkerContext) -> None:
        self._active.append(w)
        self._active.sort(key=lambda c: c.index)  # deterministic order

    def _ensure_floor(self) -> None:
        """Hold at least one lease (retry happens in the submit loop)."""
        with self._lock:
            if not self._active:
                w = self.arbiter.lease(self.pred.name)
                if w is not None:
                    self._insert(w)

    def _on_worker_idle(self, w: WorkerContext) -> bool:
        """Scale-down handshake (called from the worker's own thread).

        True == retire: the lease is released and the calling thread must
        exit immediately. All bookkeeping happens under the router lock,
        so no batch can be routed to ``w`` concurrently with retirement —
        and a batch that raced into the queue before we took the lock
        vetoes it."""
        with self._lock:
            if not self.arbiter.scale_down_enabled:
                return False
            if w not in self._active or len(self._active) <= 1:
                return False  # never drop below the one-worker floor
            if w.pinned > 0:
                return False  # a submit is in flight toward this worker
            if len(w.queue) > 0:
                return False  # a batch raced in: keep serving
            if self._virtual_drain:
                # deterministic verdict: retire only when the worker's
                # virtual busy horizon lags the router's observed virtual
                # frontier by at least the drain threshold — i.e. it has
                # been idle that long in SIMULATED time, regardless of
                # wall-clock thread scheduling
                idle_v = self._sim_frontier \
                    - self.clock.resource_busy_until(w.wid)
                if idle_v < self._drain_threshold:
                    return False
            self._active.remove(w)
            w.activated = False     # re-leasable: activate() restarts
            w._thread = None
            self.arbiter.release(self.pred.name, w)
            self.retirements += 1
            return True

    def _maybe_scale_up(self, batch: RoutingBatch):
        """Lease one more slot under saturation; returns the new worker.

        WallClock: queue backpressure (all active input queues full).
        SimClock: deterministic — every active worker's virtual busy
        horizon is past the batch's virtual arrival, i.e. the batch would
        WAIT (the utilization proxy the paper reads from the device).

        The caller must ``activate()`` the returned worker (OUTSIDE this
        router's lock — activation may warm-compile a kernel): a scale-up
        lease is granted under live traffic, and only an activated worker
        has the idle timer that can retire it — a leased-but-threadless
        context would strand its slot if the stream dried up before a
        batch was routed to it."""
        with self._lock:
            active = self._active
            if not active or len(active) >= self.max_workers:
                return None
            if isinstance(self.clock, SimClock):
                saturated = all(
                    self.clock.resource_busy_until(w.wid) > batch.sim_ready
                    for w in active
                )
            else:
                saturated = all(
                    len(w.queue) >= w.queue.capacity for w in active
                )
            if not saturated:
                return None
            w = self.arbiter.lease(self.pred.name)
            if w is not None:
                self._insert(w)
            return w

    def submit(self, batch: RoutingBatch) -> bool:
        """Route a batch to a worker (blocking; scales up under saturation).

        Returns True once the batch is accepted by a worker queue; every
        failure path RAISES (ClosedError from a stopped worker's queue,
        RuntimeError on floor starvation) — there is no silent False, so
        a caller that ignores the return value still cannot lose a batch
        without an exception crossing it (the eddy shard decrements the
        in-flight tracker on that exception).

        Thread-safe for the N-shard eddy core: the router lock is held only
        for the choose/pin bookkeeping; the blocking queue put, worker
        activation, and proxy-load reduction all run outside it, and the
        blocking waits land on per-worker condition variables — concurrent
        shard submits to different workers never serialize on one CV."""
        # data-aware proxy load (§5.3), computed OUTSIDE the router lock:
        # it reduces over the batch's columns and must not serialize
        # against worker retirement callbacks
        load = self.pred.udf.proxy(
            {c: batch.data[c] for c in self.pred.udf.columns}
        ) if batch.rows else 0.0
        starved_since = None
        while True:
            self._ensure_floor()
            grown = self._maybe_scale_up(batch)
            if grown is not None:
                # outside the router lock: activation may warm-compile
                # (GACU ensure_ready) and must not serialize against
                # retirement callbacks. N routing shards may submit
                # concurrently; WorkerContext.activate is internally
                # locked, so racing activations start exactly one thread
                grown.activate()
            with self._lock:
                if self._virtual_drain and batch.sim_ready > self._sim_frontier:
                    self._sim_frontier = batch.sim_ready
                workers = list(self._active)
                if workers:
                    worker = self.policy.choose(workers, batch, self.stats)
                    # proactive load accounting; PIN the chosen worker
                    # under the lock so its lease cannot retire while the
                    # (possibly blocking) queue put below runs lock-free
                    self.stats.add_load(worker.wid, load)
                    worker.pinned += 1
                else:
                    worker = None  # floor lease denied: back off, retry
            if worker is None:
                now = time.monotonic()
                if starved_since is None:
                    starved_since = now
                elif now - starved_since > FLOOR_STARVATION_DEADLINE_S:
                    # e.g. a bounded shared pool fully held by rivals under
                    # a policy that never releases (StaticPartition): this
                    # predicate can never run, so the query can never
                    # finish — surface it instead of spinning silently
                    raise RuntimeError(
                        f"predicate {self.pred.name!r} starved: floor "
                        f"lease denied for {FLOOR_STARVATION_DEADLINE_S}s "
                        f"(device pool exhausted by other predicates and "
                        f"nothing scaled down); arbiter counters: "
                        f"{self.arbiter.counters()}"
                    )
                time.sleep(_FLOOR_RETRY_SLEEP_S)
                continue
            starved_since = None
            try:
                ok = worker.submit(batch, timeout=0.05)
            finally:
                with self._lock:
                    worker.pinned -= 1
            if ok:
                return True
            # queue full: undo accounting, scale, retry
            self.stats.finish_load(worker.wid, load)

    def queue_depth(self) -> int:
        return sum(len(w.queue) for w in self.workers)

    def stop(self) -> None:
        for w in self.workers:
            w.stop()
        self.arbiter.unregister(self.pred.name)
        with self._lock:
            # the arbiter released every slot above: reporting the old
            # active list would fabricate leases that no longer exist
            self._active = []
