"""Laminar router (§5): per-predicate elastic parallelism with GACU.

Greedy-allocation-conservative-use: ``max_workers`` contexts per predicate
are created up front (cheap — no compilation, no device buffers), but a
worker only initializes when the router first routes a batch to it. The
router activates an additional worker whenever every active worker's input
queue is saturated (the utilization proxy: queue backpressure ==
device-idle opportunity), up to the configured ceiling — "spawning through
routing", no pipeline surgery mid-query.

Device placement: workers are assigned to device groups round-robin at
construction; the DeviceAlternating policy keeps consecutive batches on
alternating devices (the paper's GPU-aware load balancing when scaling out).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.policies import LaminarPolicy, RoundRobin
from repro.core.queues import CentralQueue
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.core.worker import WorkerContext

GACU_MAX_WORKERS = 50  # paper's hardcoded per-device ceiling


class LaminarRouter:
    def __init__(
        self,
        pred: Predicate,
        central: CentralQueue,
        stats: StatsBoard,
        *,
        cache: Optional[ReuseCache] = None,
        clock=None,
        policy: Optional[LaminarPolicy] = None,
        max_workers: int = GACU_MAX_WORKERS,
        devices: Sequence[str] = ("cpu",),
        serial_fraction: float = 0.0,
        on_error=None,
    ):
        self.pred = pred
        self.stats = stats
        self.policy = policy or RoundRobin()
        self.clock = clock
        self.max_workers = max(1, max_workers)
        # GREEDY allocation of worker contexts (lazy until first batch):
        self.workers: List[WorkerContext] = [
            WorkerContext(
                wid=f"{pred.name}#{i}",
                pred=pred,
                central=central,
                stats=stats,
                cache=cache,
                clock=clock,
                device_group=devices[i % len(devices)],
                serial_fraction=serial_fraction,
                on_error=on_error,
            )
            for i in range(self.max_workers)
        ]
        self.active_n = 1  # CONSERVATIVE use: start with a single worker

    # ------------------------------------------------------------------ #
    @property
    def active_workers(self) -> List[WorkerContext]:
        return self.workers[: self.active_n]

    def _maybe_scale_up(self, batch: RoutingBatch) -> None:
        """Activate one more context under saturation.

        WallClock: queue backpressure (all active input queues full).
        SimClock: deterministic — every active worker's virtual busy
        horizon is past the batch's virtual arrival, i.e. the batch would
        WAIT (the utilization proxy the paper reads from the device)."""
        if self.active_n >= self.max_workers:
            return
        from repro.core.simclock import SimClock

        if isinstance(self.clock, SimClock):
            if all(
                self.clock.resource_busy_until(w.wid) > batch.sim_ready
                for w in self.active_workers
            ):
                self.active_n += 1
        elif all(len(w.queue) >= w.queue.capacity for w in self.active_workers):
            self.active_n += 1

    def submit(self, batch: RoutingBatch) -> None:
        """Route a batch to a worker (blocking; scales up under saturation)."""
        while True:
            self._maybe_scale_up(batch)
            worker = self.policy.choose(self.active_workers, batch, self.stats)
            # proactive load accounting for the data-aware policy (§5.3)
            load = self.pred.udf.proxy(
                {c: batch.data[c] for c in self.pred.udf.columns}
            ) if batch.rows else 0.0
            self.stats.add_load(worker.wid, load)
            if worker.submit(batch, timeout=0.05):
                return
            # queue full: undo accounting, scale, retry
            self.stats.finish_load(worker.wid, load)

    def queue_depth(self) -> int:
        return sum(len(w.queue) for w in self.workers)

    def stop(self) -> None:
        for w in self.workers:
            w.stop()
