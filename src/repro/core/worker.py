"""Predicate workers (§3.2 step 5, §5.1 GACU, §5.2 elastic leases).

A WorkerContext is pre-created greedily but allocates nothing until the
first batch is routed to it ("spawning through routing"). Evaluation:
cache probe -> compute only misses (bucketed) -> mask -> eager
materialization -> reinsert into the central queue. Timing goes through the
Clock abstraction so the identical code path runs wall-clock (production)
or simulated (deterministic scheduling benchmarks).

MICRO-BATCH COALESCING (§5.1 utilization): when the context holds a
``CoalescePlanner`` (core/coalesce.py), a dequeue may drain additional
queued batches — non-blocking first, then waiting up to the plan's latency
budget — and evaluate them as ONE fused launch through the identical
cache-probe -> bucketed-launch -> mask pipeline (``evaluate_fused``).  The
fused mask is split back at the recorded segment boundaries
(``batch.split_back``), so each output batch is bit-identical to what the
uncoalesced path would have produced: same bid, visited set, surviving
row multiset, circulation order, and one output per input batch (the
eddy in-flight tracker counts split outputs exactly like unfused ones).
Statistics credit tickets/wins per original segment but cost per fused
launch, and the per-launch (rows, seconds) sample feeds the fixed+marginal
decomposition the adaptive planner learns from.  The planner DECLINES to
fuse (plan() -> None) when it has no launch-overhead evidence or the
predicate is already amortized — then this module is byte-for-byte the
old single-batch loop.

Elastic lifecycle (§5.2): a worker holds a *lease* on a device slot (see
core/resources.py). When its input queue has been idle past
``idle_timeout`` seconds it offers to retire via ``on_idle``; if the
router accepts (scale-down), the thread exits and the slot returns to the
DevicePool for another predicate to claim. A retired context can be
re-leased later — ``activate()`` simply starts a fresh thread.

Per-executor launch attribution: each worker thread tags itself with its
executor's ``launch_token`` so kernel-launch timing hooks registered by
that executor (thread-affine, see kernels/launch.py) only observe its own
launches — concurrent executors in one process never cross-record.

FAILURE SEMANTICS (core/faults.py; executor knob ``on_fault``):

* ``fail_fast`` (default, and whenever no FaultConfig is supplied):
  ``evaluate_resilient`` delegates straight to ``evaluate_predicate`` —
  the pre-fault-tolerance path, byte-for-byte — and any evaluation
  exception aborts the query via ``on_error``.  The worker DOES decrement
  the in-flight tracker for every batch it drops on the error path, so an
  errored batch can never wedge the termination barrier.
* ``retry``: each failed attempt is recorded in the FaultLedger
  (error-rate EMA + consecutive count) and retried up to
  ``max_attempts`` with capped exponential backoff + seeded jitter —
  under SimClock the delay advances the batch's VIRTUAL ready time, never
  a wall sleep, so injected timelines stay bit-exact.  A batch that
  exhausts its attempts is a POISON BATCH: it completes with a
  conservative pass-through verdict (all rows kept, flagged in
  ``batch.passthrough``) so the row-id-multiset and termination
  invariants hold.  ``quarantine_after`` consecutive failures quarantine
  the PREDICATE: the eddy stops routing to it (skips are logged) and any
  batch already in its queue passes through.
* ``degrade``: retry semantics plus, after ``degrade_after`` consecutive
  failures, the UDF is switched to its reference path
  (``UDF.fallback_fn``) — injected ``compiled_only`` faults stop firing,
  modelling a bug in the compiled executable that the interpreter
  escapes.  No fallback -> falls through to quarantine.
* Corrupt outputs (wrong leading row count; wrong dtype vs the UDF's
  learned ``out_spec`` under injection) raise ``CorruptOutputError``
  BEFORE the result can enter the reuse cache, and count as failures.
* A FUSED (coalesced) launch that fails is un-fused: one ledger failure
  for the group attempt, then each constituent retries individually so a
  poison batch is isolated alone rather than poisoning its whole group.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field, replace as _replace
from typing import Callable, List, Optional

import numpy as np

from repro.core.batch import RoutingBatch, concat, split_back
from repro.core.cache import ReuseCache
from repro.core.coalesce import CoalescePlanner
from repro.core.faults import (
    CorruptOutputError, FaultConfig, FaultLedger, FaultPlan, LaunchWatchdog,
    backoff_delay,
)
from repro.core.queues import BoundedQueue, CentralQueue, ClosedError
from repro.core.simclock import SimClock
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch


def _checked_outputs(pred, data, rows: int, faults, clock) -> np.ndarray:
    """One evaluation through the (optional) fault-injection seam, with
    output validation BEFORE the caller may cache the result.

    The leading-dimension check always runs (a wrong row count would
    corrupt the mask/filter contract silently); the dtype check against
    the UDF's learned ``out_spec`` runs only under injection, where a
    ``corrupt`` spec deliberately flips it — real UDFs are allowed dtype
    drift (the cache merge already promotes dtypes)."""
    if faults is None:
        outputs = pred.evaluate_outputs(data)
    else:
        outputs = faults.invoke(pred, data, clock)
    out = np.asarray(outputs)
    if out.ndim == 0 or out.shape[0] != rows:
        raise CorruptOutputError(
            f"{pred.name}: expected {rows} output rows, got shape {out.shape}"
        )
    if faults is not None:
        spec = getattr(pred.udf, "out_spec", None)
        if spec is not None and out.dtype != spec[0]:
            raise CorruptOutputError(
                f"{pred.name}: output dtype {out.dtype} != learned {spec[0]}"
            )
    return out


def _evaluate_with_cache(pred, batch, data, *, cache, stats, faults=None,
                         clock=None):
    """Cache probe -> compute misses -> vectorized hit/miss merge.

    Returns ``(outputs, wall_seconds, computed_rows, compute_data)`` where
    ``computed_rows`` is how many rows actually launched (0 on a full
    cache hit) and ``compute_data`` the column dict that was computed
    (None when nothing was) — the compute-only proxy load, so the
    proxy->seconds rate is never fed a full batch's load against a
    near-zero cached wall time.  Output validation (``_checked_outputs``)
    precedes every ``cache.put_batch``, so a corrupt result can never
    poison the reuse cache."""
    rows = batch.rows
    if cache is not None and pred.cacheable:
        # batch-aware probe: a layered cache digests the row payloads so
        # content-identical rows hit even under fresh row ids; the id-keyed
        # base cache ignores the payload argument
        hits, vals = cache.probe_batch(pred.udf.name, batch.row_ids, data)
        stats[pred.name].record_cache(rows, int(hits.sum()))
        if hits.any():
            miss = ~hits
            computed_rows = int(miss.sum())
            hit_vals = np.stack(
                [np.asarray(vals[i]) for i in np.nonzero(hits)[0]]
            )
            if computed_rows:
                sub = {c: v[miss] for c, v in data.items()}
                t0 = time.perf_counter()
                sub_out = _checked_outputs(pred, sub, computed_rows,
                                           faults, clock)
                wall = time.perf_counter() - t0
                cache.put_batch(pred.udf.name, batch.row_ids[miss], sub,
                                sub_out)
                # fancy-index scatter instead of the old per-index Python
                # loop + full-batch np.stack; dtype promotion matches what
                # stacking mixed hit/computed values used to produce
                outputs = np.empty(
                    (rows,) + sub_out.shape[1:],
                    np.promote_types(sub_out.dtype, hit_vals.dtype),
                )
                outputs[miss] = sub_out
                outputs[hits] = hit_vals
                return outputs, wall, computed_rows, sub
            outputs = np.empty((rows,) + hit_vals.shape[1:], hit_vals.dtype)
            outputs[hits] = hit_vals
            return outputs, 0.0, 0, None
        t0 = time.perf_counter()
        outputs = _checked_outputs(pred, data, rows, faults, clock)
        wall = time.perf_counter() - t0
        cache.put_batch(pred.udf.name, batch.row_ids, data, outputs)
        return outputs, wall, rows, data
    t0 = time.perf_counter()
    outputs = _checked_outputs(pred, data, rows, faults, clock)
    wall = time.perf_counter() - t0
    return outputs, wall, rows, data


def _sim_cost(pred, computed_rows: int, data, wall: float) -> float:
    if pred.udf.cost_model is None:
        return wall
    try:
        # data-aware cost models see the batch columns (UC4: LLM
        # cost proportional to text length, not just row count)
        return pred.udf.cost_model(computed_rows, data)
    except TypeError:
        return pred.udf.cost_model(computed_rows)


def evaluate_predicate(
    pred: Predicate,
    batch: RoutingBatch,
    *,
    stats: StatsBoard,
    cache: Optional[ReuseCache],
    clock,
    worker_id: str,
    device_group: str,
    serial_fraction: float = 0.0,
    faults: Optional[FaultPlan] = None,
) -> RoutingBatch:
    """Evaluate one predicate on one batch; returns the filtered batch."""
    rows = batch.rows
    if rows == 0:
        return batch.mark_visited(pred.name)

    data = {c: batch.data[c] for c in pred.udf.columns}
    outputs, wall, computed_rows, compute_data = _evaluate_with_cache(
        pred, batch, data, cache=cache, stats=stats, faults=faults,
        clock=clock,
    )

    finish = None
    if isinstance(clock, SimClock):
        cost = _sim_cost(pred, computed_rows, data, wall)
        if faults is not None:
            # injected hang under SimClock: extra VIRTUAL occupancy
            cost += faults.take_extra_cost()
        finish = clock.occupy_shared(
            worker_id, device_group, cost, serial_fraction, ready=batch.sim_ready
        )
        seconds = cost
    else:
        seconds = wall

    mask = pred.mask_from_outputs(outputs)
    out_batch = batch.filter(mask).mark_visited(pred.name)
    if finish is not None:
        out_batch = _replace(out_batch, sim_ready=finish)
    stats[pred.name].record_eval(
        rows, out_batch.rows, seconds, bucket=stats.bucket_of(batch),
        computed_rows=computed_rows,
    )
    # proxy->seconds rate: compute-only load over compute-only time. The
    # old call fed the FULL batch's proxy load even when most rows were
    # cache hits and wall ~= 0, corrupting the rate (and risking
    # div-by-near-zero on full hits) — full-hit evaluations are skipped.
    if computed_rows and compute_data is not None:
        stats.note_proxy_rate(pred.udf.proxy(compute_data), seconds)
    return out_batch


def evaluate_fused(
    pred: Predicate,
    batches: List[RoutingBatch],
    *,
    stats: StatsBoard,
    cache: Optional[ReuseCache],
    clock,
    worker_id: str,
    device_group: str,
    serial_fraction: float = 0.0,
    faults: Optional[FaultPlan] = None,
) -> List[RoutingBatch]:
    """Evaluate ``batches`` as ONE fused launch; returns per-bid outputs.

    The fused batch goes through the identical cache-probe ->
    bucketed-launch -> mask pipeline as a single batch, then the mask is
    split at the segment boundaries so every output is bit-identical to
    individual evaluation (see the coalescing contract in core/batch.py).
    Under SimClock the fused occupancy is ONE launch: cost_model(total
    computed rows) = one fixed launch term + summed per-row terms, started
    at the LAST constituent's virtual arrival; every split output inherits
    the single fused finish as its ``sim_ready``."""
    assert batches and all(b.rows > 0 for b in batches)
    fused, segments = concat(batches)
    data = {c: fused.data[c] for c in pred.udf.columns}
    outputs, wall, computed_rows, compute_data = _evaluate_with_cache(
        pred, fused, data, cache=cache, stats=stats, faults=faults,
        clock=clock,
    )

    finish = None
    if isinstance(clock, SimClock):
        cost = _sim_cost(pred, computed_rows, data, wall)
        if faults is not None:
            cost += faults.take_extra_cost()
        finish = clock.occupy_shared(
            worker_id, device_group, cost, serial_fraction, ready=fused.sim_ready
        )
        seconds = cost
    else:
        seconds = wall

    mask = pred.mask_from_outputs(outputs)
    outs = split_back(segments, mask, visit=pred.name, sim_ready=finish)
    stats[pred.name].record_fused_eval(
        [
            (b.rows, o.rows, stats.bucket_of(b))
            for b, o in zip(batches, outs)
        ],
        seconds,
        computed_rows=computed_rows,
    )
    if computed_rows and compute_data is not None:
        stats.note_proxy_rate(pred.udf.proxy(compute_data), seconds)
    return outs


def passthrough_batch(batch: RoutingBatch, pred_name: str) -> RoutingBatch:
    """Complete ``batch`` with a conservative quarantine verdict: every
    row PASSES (no row is dropped on faulty evidence) and the predicate is
    flagged in ``batch.passthrough`` for downstream auditing.  The batch
    completes like any other, so the in-flight termination barrier and the
    row-id-multiset invariant hold unchanged."""
    return batch.mark_passthrough(pred_name)


def evaluate_resilient(
    pred: Predicate,
    batch: RoutingBatch,
    *,
    stats: StatsBoard,
    cache: Optional[ReuseCache],
    clock,
    worker_id: str,
    device_group: str,
    serial_fraction: float = 0.0,
    faults: Optional[FaultPlan] = None,
    ledger: Optional[FaultLedger] = None,
    config: Optional[FaultConfig] = None,
    watchdog: Optional[LaunchWatchdog] = None,
) -> RoutingBatch:
    """Fault-policy wrapper around ``evaluate_predicate`` implementing the
    retry / degrade / quarantine contract (module docstring).

    With no ``config``/``ledger`` (``on_fault="fail_fast"``) this is a
    direct delegation — the pre-fault-tolerance path, byte-for-byte."""
    if config is None or ledger is None:
        return evaluate_predicate(
            pred, batch, stats=stats, cache=cache, clock=clock,
            worker_id=worker_id, device_group=device_group,
            serial_fraction=serial_fraction, faults=faults,
        )
    if batch.rows == 0:
        return batch.mark_visited(pred.name)
    if ledger.is_quarantined(pred.name):
        if not ledger.begin_probe(pred.name):
            # raced into the worker queue after quarantine tripped: same
            # conservative verdict the routing-level skip would have applied
            ledger.note_quarantined_batch(pred.name, batch.rows)
            return passthrough_batch(batch, pred.name)
        # recovery probe (FaultConfig.probe_after_skips): the eddy routed
        # this ONE batch at the quarantined predicate deliberately — a
        # single attempt, no retries.  Success lifts the quarantine and
        # normal routing resumes; failure passes the batch through and
        # re-arms the skip window.
        try:
            out = evaluate_predicate(
                pred, batch, stats=stats, cache=cache, clock=clock,
                worker_id=worker_id, device_group=device_group,
                serial_fraction=serial_fraction, faults=faults,
            )
        except ClosedError:
            raise
        except Exception as e:
            ledger.note_failure(pred.name, error=e)
            ledger.end_probe(pred.name, success=False)
            ledger.note_quarantined_batch(pred.name, batch.rows)
            return passthrough_batch(batch, pred.name)
        ledger.note_success(pred.name)
        ledger.end_probe(pred.name, success=True)
        return out
    simulated = getattr(clock, "simulated", False)
    attempt = 0
    while True:
        attempt += 1
        token = watchdog.begin(pred.name) if watchdog is not None else None
        t0 = time.perf_counter()
        try:
            out = evaluate_predicate(
                pred, batch, stats=stats, cache=cache, clock=clock,
                worker_id=worker_id, device_group=device_group,
                serial_fraction=serial_fraction, faults=faults,
            )
        except ClosedError:
            raise  # shutdown in progress, not an evaluation fault
        except Exception as e:
            consecutive = ledger.note_failure(pred.name, error=e)
            if (config.mode == "degrade"
                    and consecutive >= config.degrade_after
                    and not pred.udf.degraded and pred.udf.degrade()):
                ledger.note_degraded(pred.name)
            if consecutive >= config.quarantine_after:
                ledger.set_quarantined(pred.name)
            if ledger.is_quarantined(pred.name) \
                    or attempt >= config.max_attempts:
                # poison batch: conservative pass-through completion
                ledger.note_quarantined_batch(pred.name, batch.rows)
                return passthrough_batch(batch, pred.name)
            ledger.note_retry(pred.name)
            delay = backoff_delay(config, attempt,
                                  ledger.jitter_rng(pred.name))
            if simulated:
                # virtual backoff: the retry cannot start before the
                # delay elapses in SIMULATED time — never a wall sleep
                batch = _replace(batch, sim_ready=batch.sim_ready + delay)
            elif delay > 0.0:
                clock.sleep(delay)
            continue
        finally:
            if token is not None:
                watchdog.end(token)
        ledger.note_success(pred.name)
        if config.launch_deadline_s is not None:
            # post-hoc deadline accounting: virtual turnaround under
            # SimClock (the watchdog thread never runs there), wall
            # elapsed otherwise (the live watchdog additionally flags
            # launches still in flight past the deadline)
            elapsed = (out.sim_ready - batch.sim_ready if simulated
                       else time.perf_counter() - t0)
            if elapsed > config.launch_deadline_s:
                ledger.note_deadline(pred.name)
        return out


@dataclass
class WorkerContext:
    """GACU worker: greedy allocation, conservative (lazy) use.

    ``index`` is the context's position in its predicate's greedy
    allocation (stable activation order); ``idle_timeout``/``on_idle``
    implement the §5.2 scale-down handshake; ``launch_token`` tags the
    worker thread for per-executor kernel-launch attribution; ``coalesce``
    (a per-predicate CoalescePlanner shared across the predicate's
    workers) enables micro-batch fusing on the dequeue path."""

    wid: str
    pred: Predicate
    central: CentralQueue
    stats: StatsBoard
    cache: Optional[ReuseCache]
    clock: object
    device_group: str = "cpu"
    serial_fraction: float = 0.0
    queue: BoundedQueue = field(default_factory=lambda: BoundedQueue(2))
    activated: bool = False
    batches_done: int = 0
    _thread: Optional[threading.Thread] = None
    on_error: Optional[object] = None
    index: int = 0
    idle_timeout: Optional[float] = None
    on_idle: Optional[Callable[["WorkerContext"], bool]] = None
    launch_token: Optional[object] = None
    coalesce: Optional[CoalescePlanner] = None
    # fault tolerance (core/faults.py): the injection plan (tests/chaos
    # bench), the shared per-predicate ledger, the retry policy (None ==
    # fail_fast), the wall-clock launch watchdog, and the executor's
    # in-flight tracker — decremented for every batch dropped on an error
    # path so the termination barrier cannot leak
    fault_plan: Optional[FaultPlan] = None
    ledger: Optional[FaultLedger] = None
    fault_config: Optional[FaultConfig] = None
    watchdog: Optional[LaunchWatchdog] = None
    tracker: Optional[object] = None
    # submits in flight (set under the router lock): a pinned worker must
    # not retire, or the in-flight batch would land in a dead queue
    pinned: int = 0
    # guards the activated check-and-set: with N routing shards, two
    # shards can choose the same worker concurrently and both reach
    # activate() — without the lock they would race the flag and start
    # two threads for one context
    _activate_lock: threading.Lock = field(default_factory=threading.Lock)

    def activate(self) -> None:
        """Called by the Laminar router when the first batch is routed here.

        Re-entrant across retirement: a context whose lease was retired
        (thread exited, ``activated`` reset by the router) starts a fresh
        thread on the next routed batch. Safe to race from multiple
        routing shards: exactly one caller starts the thread."""
        with self._activate_lock:
            if self.activated:
                return
            self.activated = True
            self.pred.udf.ensure_ready()  # lazy context allocation (GACU)
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=f"worker-{self.wid}")
            self._thread.start()

    def submit(self, batch: RoutingBatch, timeout: Optional[float] = None) -> bool:
        self.activate()
        return self.queue.put(batch, timeout)

    # ------------------------- coalescing ------------------------- #
    def _drain_coalesce(self, first: RoutingBatch) -> List[RoutingBatch]:
        """Collect the fuse group for this dequeue: ``[first]`` plus up to
        ``plan.max_batches - 1`` more queued batches, draining
        non-blocking first and then waiting out the latency budget while
        still short of ``plan.target_rows``.  A closed queue ends the
        drain — whatever is in hand still gets evaluated."""
        planner = self.coalesce
        if planner is None:
            return [first]
        plan = planner.plan(first.rows)
        if plan is None:
            return [first]
        batches, rows = [first], first.rows
        deadline = None
        while rows < plan.target_rows and len(batches) < plan.max_batches:
            got = self.queue.get_many(plan.max_batches - len(batches))
            if got:
                batches.extend(got)
                rows += sum(b.rows for b in got)
                continue
            if plan.max_wait_s <= 0:
                break
            now = time.monotonic()
            if deadline is None:
                deadline = now + plan.max_wait_s
            remaining = deadline - now
            if remaining <= 0:
                break
            try:
                batches.append(self.queue.get(timeout=remaining))
                rows += batches[-1].rows
            except (TimeoutError, ClosedError):
                break
        planner.note_fused(len(batches))
        return batches

    def _evaluate_group(self, batches: List[RoutingBatch]) -> List[RoutingBatch]:
        """Evaluate a fuse group, preserving per-batch output order.

        Zero-row batches never launch anything and take the single-batch
        path (mark-visited only); the non-empty remainder fuses into one
        launch when there are at least two."""
        fusable = [b for b in batches if b.rows > 0]
        if len(fusable) < 2 or (
            # quarantined: per-batch path so the pass-through / recovery-
            # probe bookkeeping in evaluate_resilient sees every batch
            self.ledger is not None
            and self.ledger.is_quarantined(self.pred.name)
        ):
            return [self._evaluate_one(b) for b in batches]
        try:
            fused_outs = iter(evaluate_fused(
                self.pred, fusable,
                stats=self.stats, cache=self.cache, clock=self.clock,
                worker_id=self.wid, device_group=self.device_group,
                serial_fraction=self.serial_fraction,
                faults=self.fault_plan,
            ))
        except ClosedError:
            raise
        except Exception as e:
            if self.fault_config is None or self.ledger is None:
                raise  # fail_fast: the pre-fault-tolerance abort path
            # fused-launch failure: one ledger failure for the group
            # attempt, then UN-FUSE — each batch retries individually so
            # a poison batch is quarantined alone, not its whole group
            self.ledger.note_failure(self.pred.name, error=e)
            return [self._evaluate_one(b) for b in batches]
        return [
            next(fused_outs) if b.rows > 0 else b.mark_visited(self.pred.name)
            for b in batches
        ]

    def _evaluate_one(self, b: RoutingBatch) -> RoutingBatch:
        return evaluate_resilient(
            self.pred, b,
            stats=self.stats, cache=self.cache, clock=self.clock,
            worker_id=self.wid, device_group=self.device_group,
            serial_fraction=self.serial_fraction,
            faults=self.fault_plan, ledger=self.ledger,
            config=self.fault_config, watchdog=self.watchdog,
        )

    def _run(self) -> None:
        if self.launch_token is not None:
            # thread-affine launch attribution: kernel timing hooks keyed
            # by this executor's token observe this thread's launches only
            kernel_launch.set_launch_context(self.launch_token)
        while True:
            try:
                batch = self.queue.get(timeout=self.idle_timeout)
            except TimeoutError:
                # queue idle past the drain threshold: offer to retire.
                # The router decides under its own lock (floor of one
                # worker, queue still empty, policy allows scale-down) and
                # performs all bookkeeping before we return — after a True
                # verdict this thread must touch nothing and exit.
                if self.on_idle is not None and self.on_idle(self):
                    return
                continue
            except ClosedError:
                return
            batches = [batch]
            reinserted = 0
            try:
                batches = self._drain_coalesce(batch)
                outs = self._evaluate_group(batches)
                for b, out in zip(batches, outs):
                    load = self.pred.udf.proxy(
                        {c: b.data[c] for c in self.pred.udf.columns}
                    ) if b.rows else 0.0
                    self.stats.finish_load(self.wid, load)
                    self.batches_done += 1
                    self.central.put_worker(out)
                    reinserted += 1
            except ClosedError:
                self._untrack(len(batches) - reinserted)
                return
            except Exception as e:  # propagate to the executor
                self._untrack(len(batches) - reinserted)
                if self.on_error is not None:
                    self.on_error(e, traceback.format_exc())
                return

    def _untrack(self, dropped: int) -> None:
        """Decrement the in-flight tracker for batches this worker dropped
        on an error/shutdown path (they will never complete): without
        this, an errored batch leaks the termination barrier and sibling
        shards poll until their timeout instead of exiting."""
        if self.tracker is None:
            return
        for _ in range(dropped):
            self.tracker.finished()

    def stop(self) -> None:
        self.queue.close()
