"""Predicate workers (§3.2 step 5, §5.1 GACU, §5.2 elastic leases).

A WorkerContext is pre-created greedily but allocates nothing until the
first batch is routed to it ("spawning through routing"). Evaluation:
cache probe -> compute only misses (bucketed) -> mask -> eager
materialization -> reinsert into the central queue. Timing goes through the
Clock abstraction so the identical code path runs wall-clock (production)
or simulated (deterministic scheduling benchmarks).

Elastic lifecycle (§5.2): a worker holds a *lease* on a device slot (see
core/resources.py). When its input queue has been idle past
``idle_timeout`` seconds it offers to retire via ``on_idle``; if the
router accepts (scale-down), the thread exits and the slot returns to the
DevicePool for another predicate to claim. A retired context can be
re-leased later — ``activate()`` simply starts a fresh thread.

Per-executor launch attribution: each worker thread tags itself with its
executor's ``launch_token`` so kernel-launch timing hooks registered by
that executor (thread-affine, see kernels/launch.py) only observe its own
launches — concurrent executors in one process never cross-record.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.queues import BoundedQueue, CentralQueue, ClosedError
from repro.core.simclock import SimClock, WallClock
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch


def evaluate_predicate(
    pred: Predicate,
    batch: RoutingBatch,
    *,
    stats: StatsBoard,
    cache: Optional[ReuseCache],
    clock,
    worker_id: str,
    device_group: str,
    serial_fraction: float = 0.0,
) -> RoutingBatch:
    """Evaluate one predicate on one batch; returns the filtered batch."""
    rows = batch.rows
    if rows == 0:
        return batch.mark_visited(pred.name)

    data = {c: batch.data[c] for c in pred.udf.columns}
    computed_rows = rows

    if cache is not None and pred.cacheable:
        # batch-aware probe: a layered cache digests the row payloads so
        # content-identical rows hit even under fresh row ids; the id-keyed
        # base cache ignores the payload argument
        hits, vals = cache.probe_batch(pred.udf.name, batch.row_ids, data)
        stats[pred.name].record_cache(rows, int(hits.sum()))
        if hits.any():
            miss = ~hits
            computed_rows = int(miss.sum())
            outputs = [None] * rows
            for i in np.nonzero(hits)[0]:
                outputs[i] = vals[i]
            if computed_rows:
                sub = {c: v[miss] for c, v in data.items()}
                t0 = time.perf_counter()
                sub_out = pred.evaluate_outputs(sub)
                wall = time.perf_counter() - t0
                cache.put_batch(pred.udf.name, batch.row_ids[miss], sub,
                                sub_out)
                for j, i in enumerate(np.nonzero(miss)[0]):
                    outputs[i] = sub_out[j]
            else:
                wall = 0.0
            outputs = np.stack([np.asarray(o) for o in outputs])
        else:
            t0 = time.perf_counter()
            outputs = pred.evaluate_outputs(data)
            wall = time.perf_counter() - t0
            cache.put_batch(pred.udf.name, batch.row_ids, data, outputs)
    else:
        t0 = time.perf_counter()
        outputs = pred.evaluate_outputs(data)
        wall = time.perf_counter() - t0

    finish = None
    if isinstance(clock, SimClock):
        if pred.udf.cost_model is not None:
            try:
                # data-aware cost models see the batch columns (UC4: LLM
                # cost proportional to text length, not just row count)
                cost = pred.udf.cost_model(computed_rows, data)
            except TypeError:
                cost = pred.udf.cost_model(computed_rows)
        else:
            cost = wall
        finish = clock.occupy_shared(
            worker_id, device_group, cost, serial_fraction, ready=batch.sim_ready
        )
        seconds = cost
    else:
        seconds = wall

    mask = pred.mask_from_outputs(outputs)
    out_batch = batch.filter(mask).mark_visited(pred.name)
    if finish is not None:
        from dataclasses import replace as _replace

        out_batch = _replace(out_batch, sim_ready=finish)
    stats[pred.name].record_eval(
        rows, out_batch.rows, seconds, bucket=stats.bucket_of(batch)
    )
    stats.note_proxy_rate(pred.udf.proxy(data), seconds)
    return out_batch


@dataclass
class WorkerContext:
    """GACU worker: greedy allocation, conservative (lazy) use.

    ``index`` is the context's position in its predicate's greedy
    allocation (stable activation order); ``idle_timeout``/``on_idle``
    implement the §5.2 scale-down handshake; ``launch_token`` tags the
    worker thread for per-executor kernel-launch attribution."""

    wid: str
    pred: Predicate
    central: CentralQueue
    stats: StatsBoard
    cache: Optional[ReuseCache]
    clock: object
    device_group: str = "cpu"
    serial_fraction: float = 0.0
    queue: BoundedQueue = field(default_factory=lambda: BoundedQueue(2))
    activated: bool = False
    batches_done: int = 0
    _thread: Optional[threading.Thread] = None
    on_error: Optional[object] = None
    index: int = 0
    idle_timeout: Optional[float] = None
    on_idle: Optional[Callable[["WorkerContext"], bool]] = None
    launch_token: Optional[object] = None
    # submits in flight (set under the router lock): a pinned worker must
    # not retire, or the in-flight batch would land in a dead queue
    pinned: int = 0
    # guards the activated check-and-set: with N routing shards, two
    # shards can choose the same worker concurrently and both reach
    # activate() — without the lock they would race the flag and start
    # two threads for one context
    _activate_lock: threading.Lock = field(default_factory=threading.Lock)

    def activate(self) -> None:
        """Called by the Laminar router when the first batch is routed here.

        Re-entrant across retirement: a context whose lease was retired
        (thread exited, ``activated`` reset by the router) starts a fresh
        thread on the next routed batch. Safe to race from multiple
        routing shards: exactly one caller starts the thread."""
        with self._activate_lock:
            if self.activated:
                return
            self.activated = True
            self.pred.udf.ensure_ready()  # lazy context allocation (GACU)
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=f"worker-{self.wid}")
            self._thread.start()

    def submit(self, batch: RoutingBatch, timeout: Optional[float] = None) -> bool:
        self.activate()
        return self.queue.put(batch, timeout)

    def _run(self) -> None:
        if self.launch_token is not None:
            # thread-affine launch attribution: kernel timing hooks keyed
            # by this executor's token observe this thread's launches only
            kernel_launch.set_launch_context(self.launch_token)
        while True:
            try:
                batch = self.queue.get(timeout=self.idle_timeout)
            except TimeoutError:
                # queue idle past the drain threshold: offer to retire.
                # The router decides under its own lock (floor of one
                # worker, queue still empty, policy allows scale-down) and
                # performs all bookkeeping before we return — after a True
                # verdict this thread must touch nothing and exit.
                if self.on_idle is not None and self.on_idle(self):
                    return
                continue
            except ClosedError:
                return
            try:
                out = evaluate_predicate(
                    self.pred, batch,
                    stats=self.stats, cache=self.cache, clock=self.clock,
                    worker_id=self.wid, device_group=self.device_group,
                    serial_fraction=self.serial_fraction,
                )
                load = self.pred.udf.proxy(
                    {c: batch.data[c] for c in self.pred.udf.columns}
                ) if batch.rows else 0.0
                self.stats.finish_load(self.wid, load)
                self.batches_done += 1
                self.central.put_worker(out)
            except ClosedError:
                return
            except Exception as e:  # propagate to the executor
                if self.on_error is not None:
                    self.on_error(e, traceback.format_exc())
                return

    def stop(self) -> None:
        self.queue.close()
