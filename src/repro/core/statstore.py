"""Persistent cross-query statistics store (ROADMAP: cross-query reuse).

Hydro's position (§3.3) is that UDF statistics are PROFILED, never
estimated a priori — but profiling restarted from roofline priors on every
``AQPExecutor.run()``. This store carries profiled cost/selectivity ACROSS
queries and processes (the GRACEFUL / Adaptive Cost Model argument for
profiled, drift-tracked UDF costs): canonical predicate fingerprints map
to EMA records that warm-start each run's StatsBoard and are re-observed
when the run ends.

Fingerprints
------------
``canonical_fingerprint(kernel, **config)`` builds a deterministic string
``"<kernel>|k1=v1|...|cmv=<COST_MODEL_VERSION>"`` from the kernel name,
its configuration (sorted, repr-ed — no process-randomized hashing), and
the cost-model version, so

  * the same predicate built in two processes maps to the same record;
  * two configs of one kernel (``color='black'`` vs ``'white'``) never
    share a profile;
  * bumping ``COST_MODEL_VERSION`` orphans every old record when cost
    semantics change.

UDF builders attach the fingerprint via ``UDF.fingerprint``;
``fingerprint_of(pred)`` falls back to ``udf:<name>`` for ad-hoc UDFs so
any predicate with a stable name still warm-starts.

Age decay (knobs)
-----------------
A record observed ``age`` seconds ago carries weight
``0.5 ** (age / half_life_s)``. The weight scales the warm-start's
pseudo-ticket count (``pseudo_tickets * weight``), so a stale profile
seeds a weaker prior that fresh lottery observations out-vote quickly;
below ``min_weight`` the record is not seeded at all — stale profiles
lose to fresh observations by construction. Re-observation blends with
the same weight: a record that sat unused for many half-lives is mostly
replaced by the new profile rather than averaged with it.

Defaults: ``half_life_s`` 6h, ``pseudo_tickets`` 256 (≈ a few dozen
routing batches of evidence), ``min_weight`` 0.05, observation EMA
``alpha`` 0.3. Persistence is JSON with temp-file + ``os.replace``
(atomic); a corrupt store file warns and starts cold, mirroring
``ReuseCache``.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from typing import Dict, List, Optional

# Bump when cost-model semantics change (e.g. cost_per_row units or the
# roofline seed): old profiles become unreachable under the new version's
# fingerprints instead of silently mis-seeding.
COST_MODEL_VERSION = 1

DEFAULT_HALF_LIFE_S = 6 * 3600.0
DEFAULT_PSEUDO_TICKETS = 256
DEFAULT_MIN_WEIGHT = 0.05


def canonical_fingerprint(kernel: str, *, version: int = COST_MODEL_VERSION,
                          **config) -> str:
    """Deterministic cross-process fingerprint: kernel + config + version."""
    parts = [str(kernel)]
    parts += [f"{k}={config[k]!r}" for k in sorted(config)]
    parts.append(f"cmv={version}")
    return "|".join(parts)


def fingerprint_of(pred) -> str:
    """A predicate's canonical fingerprint.

    Kernel-backed UDFs carry one from their builder (``UDF.fingerprint``);
    ad-hoc UDFs fall back to their stable name."""
    fp = getattr(pred.udf, "fingerprint", None)
    return fp or canonical_fingerprint(f"udf:{pred.udf.name}")


class StatsStore:
    """Fingerprint -> EMA cost/selectivity records, decayed by age.

    ``path=None`` keeps the store in memory (benchmarks sharing one store
    across executors); with a path, ``flush()`` persists atomically and
    construction loads tolerantly. Thread-safe: one executor may record
    while another warm-starts."""

    def __init__(self, path: Optional[str] = None, *,
                 half_life_s: float = DEFAULT_HALF_LIFE_S,
                 pseudo_tickets: int = DEFAULT_PSEUDO_TICKETS,
                 min_weight: float = DEFAULT_MIN_WEIGHT,
                 alpha: float = 0.3,
                 clock=time.time):
        self.path = path
        self.half_life_s = half_life_s
        self.pseudo_tickets = pseudo_tickets
        self.min_weight = min_weight
        self.alpha = alpha
        self.clock = clock
        self._records: Dict[str, Dict[str, float]] = {}
        self._lock = threading.RLock()
        if path and os.path.exists(path):
            self._load()

    # --------------------------- records --------------------------- #
    def get(self, fingerprint: str) -> Optional[Dict[str, float]]:
        with self._lock:
            rec = self._records.get(fingerprint)
            return dict(rec) if rec is not None else None

    def weight_of(self, record: Dict[str, float]) -> float:
        """Age-decay weight in [0, 1]: halves every ``half_life_s``."""
        age = max(0.0, self.clock() - record.get("updated_at", 0.0))
        return 0.5 ** (age / self.half_life_s)

    def observe(self, fingerprint: str, *, cost_per_row: float,
                selectivity: float, batches: int = 1) -> None:
        """Fold one run's profiled statistics into the record.

        The blend is age-weighted: the old value enters at
        ``(1 - alpha) * weight``, so a record decayed to ~0 is effectively
        replaced by the fresh profile."""
        with self._lock:
            rec = self._records.get(fingerprint)
            now = self.clock()
            if rec is None:
                rec = {
                    "cost_per_row": float(cost_per_row),
                    "selectivity": float(selectivity),
                    "batches": int(batches),
                    "updated_at": now,
                }
            else:
                w_old = (1.0 - self.alpha) * self.weight_of(rec)
                denom = self.alpha + w_old
                rec["cost_per_row"] = (
                    self.alpha * cost_per_row
                    + w_old * rec["cost_per_row"]
                ) / denom
                rec["selectivity"] = (
                    self.alpha * selectivity
                    + w_old * rec["selectivity"]
                ) / denom
                rec["batches"] = int(rec.get("batches", 0)) + int(batches)
                rec["updated_at"] = now
            self._records[fingerprint] = rec

    # -------------------------- board glue -------------------------- #
    def warm_start(self, board, predicates: List) -> Dict[str, int]:
        """Seed a StatsBoard from stored records; returns the per-name
        batch count contributed by seeds (so callers can tell seeded
        entries from genuinely-profiled ones when recording back).

        Seeding marks the entry measured, so a fully warm-started run
        skips the warmup circulation entirely — the cross-query
        equivalent of the paper's warmup phase having already happened."""
        seeded: Dict[str, int] = {}
        for p in predicates:
            rec = self.get(fingerprint_of(p))
            if rec is None:
                continue
            w = self.weight_of(rec)
            if w < self.min_weight:
                continue  # stale beyond use: let the run profile afresh
            tickets = int(round(self.pseudo_tickets * w))
            if tickets < 1:
                continue
            board.seed_prior(
                p.name,
                cost_per_row=rec["cost_per_row"],
                selectivity=rec["selectivity"],
                tickets=tickets,
            )
            seeded[p.name] = 1
        return seeded

    def record_board(self, board, predicates: List,
                     seeded: Optional[Dict[str, int]] = None) -> None:
        """Fold a finished run's board back into the store.

        Entries whose batch count never grew past their seed are skipped:
        re-observing a seed would refresh ``updated_at`` and make stale
        data look freshly profiled."""
        seeded = seeded or {}
        for p in predicates:
            try:
                st = board[p.name]
            except KeyError:
                continue
            base = seeded.get(p.name, 0)
            if st.batches <= base:
                continue
            self.observe(
                fingerprint_of(p),
                cost_per_row=st.cost(),
                selectivity=st.selectivity(),
                batches=st.batches - base,
            )

    def record_live(self, board, predicates: List,
                    bases: Dict[str, int]) -> Dict[str, int]:
        """Fold a STILL-RUNNING executor's live profile into the store.

        The multi-tenant live-prior channel (launch/serve.py QueryService):
        before dispatching a new query, the service folds each running
        executor's current board here so the newcomer's ``warm_start``
        sees its rivals' in-flight measurements, not just finished runs.

        ``bases`` maps predicate name -> batch count already folded (the
        warm-start seed on first call, then whatever this method returned
        last time); only the delta since the base is observed, so repeated
        folds never double-count evidence. Returns the updated bases."""
        out = dict(bases)
        for p in predicates:
            try:
                st = board[p.name]
            except KeyError:
                continue
            base = out.get(p.name, 0)
            if st.batches <= base:
                continue
            self.observe(
                fingerprint_of(p),
                cost_per_row=st.cost(),
                selectivity=st.selectivity(),
                batches=st.batches - base,
            )
            out[p.name] = st.batches
        return out

    # ----------------------------- disk ----------------------------- #
    def flush(self) -> None:
        """Atomic JSON snapshot (temp file + ``os.replace``)."""
        if not self.path:
            return
        with self._lock:
            payload = json.dumps(
                {"version": COST_MODEL_VERSION, "records": self._records},
                sort_keys=True,
            )
        tmp = self.path + ".tmp"
        try:
            with open(tmp, "w") as f:
                f.write(payload)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def _load(self) -> None:
        try:
            with open(self.path) as f:
                blob = json.load(f)
            records = blob["records"] if isinstance(blob, dict) else {}
            if not isinstance(records, dict):
                raise ValueError("malformed records")
            self._records = {
                str(k): dict(v) for k, v in records.items()
                if isinstance(v, dict)
            }
        except Exception as e:
            self._records = {}
            warnings.warn(
                f"StatsStore: could not load {self.path!r} ({e!r}); "
                "starting cold"
            )

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)
