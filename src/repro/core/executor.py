"""AQPExecutor — wires EddyPull + EddyRouter + Laminar routers + workers
into the executor of Fig. 2 and exposes the parent-executor pull interface
(a blocking iterator over the output queue).

Kernel cost visibility (§3.3): for the lifetime of a ``run()`` the executor
registers ``launch.connect_stats_board(self.stats)``, so every Pallas
launch a predicate makes reports its per-launch timing into the same
StatsBoard the routing policies rank on — kernel UDF cost is profiled, not
estimated, exactly like predicate-level cost. The hook is removed in
``shutdown()`` so back-to-back executors never double-count each other's
launches. The hook bus is process-global: two executors running
CONCURRENTLY in one process would cross-record each other's kernel
launches (no production path does this today; per-executor attribution
needs launch-context tagging — see ROADMAP).
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.eddy import EddyPull, EddyRouter
from repro.core.laminar import GACU_MAX_WORKERS, LaminarRouter
from repro.core.policies import EddyPolicy, HydroPolicy, LaminarPolicy, RoundRobin
from repro.core.queues import BoundedQueue, CentralQueue, ClosedError
from repro.core.simclock import WallClock
from repro.core.stats import StatsBoard
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch


class AQPExecutor:
    def __init__(
        self,
        predicates: List[Predicate],
        *,
        policy: Optional[EddyPolicy] = None,
        laminar_policy_factory=RoundRobin,
        clock=None,
        cache: Optional[ReuseCache] = None,
        central_capacity: int = 64,
        lam: float = 0.3,
        max_workers: int = GACU_MAX_WORKERS,
        devices: Optional[Dict[str, Sequence[str]]] = None,  # pred -> device groups
        serial_fraction: float = 0.0,
        warmup: bool = True,
        output_capacity: int = 1024,
        cost_alpha: float = 0.3,
    ):
        self.predicates = predicates
        self.policy = policy or HydroPolicy()
        self.clock = clock or WallClock()
        self.cache = cache
        self.stats = StatsBoard([p.name for p in predicates], cost_alpha=cost_alpha)
        self.central = CentralQueue(central_capacity, lam)
        self.output = BoundedQueue(output_capacity)
        self._error_lock = threading.Lock()
        self._worker_error = None
        self.laminars: Dict[str, LaminarRouter] = {
            p.name: LaminarRouter(
                p,
                self.central,
                self.stats,
                cache=cache,
                clock=self.clock,
                policy=laminar_policy_factory(),
                max_workers=max_workers,
                devices=(devices or {}).get(p.name, (p.resource,)),
                serial_fraction=serial_fraction,
                on_error=self._on_worker_error,
            )
            for p in predicates
        }
        self.warmup = warmup
        self._pull: Optional[EddyPull] = None
        self._router: Optional[EddyRouter] = None
        self._kernel_hook = None  # launch-timing hook, live only during run()

    # ------------------------------------------------------------------ #
    def _on_worker_error(self, exc, tb):
        with self._error_lock:
            if self._worker_error is None:
                self._worker_error = (exc, tb)
        self.output.close()
        self.central.close()

    def run(self, source: Iterable[RoutingBatch]) -> Iterator[RoutingBatch]:
        """Execute; yields completed (non-empty) batches in completion order."""
        if self._kernel_hook is None:
            # Per-launch kernel timings feed the routing StatsBoard for the
            # duration of the run; shutdown() deregisters.
            self._kernel_hook = kernel_launch.connect_stats_board(self.stats)
        self._pull = EddyPull(source, self.central)
        self._router = EddyRouter(
            self.predicates, self.central, self.output, self.laminars,
            self.stats, self.policy, self._pull,
            cache=self.cache, warmup=self.warmup,
        )
        self._pull.start()
        self._router.start()
        try:
            while True:
                try:
                    yield self.output.get(timeout=1.0)
                except TimeoutError:
                    if self._worker_error is not None:
                        break
                    continue
                except ClosedError:
                    break
        finally:
            self.shutdown()
        if self._worker_error is not None:
            exc, tb = self._worker_error
            raise RuntimeError(f"predicate worker failed:\n{tb}") from exc
        if self._pull.error is not None:
            raise self._pull.error
        if self._router.error is not None:
            raise self._router.error

    def collect(self, source: Iterable[RoutingBatch]) -> List[RoutingBatch]:
        return list(self.run(source))

    def shutdown(self) -> None:
        if self._kernel_hook is not None:
            kernel_launch.remove_launch_hook(self._kernel_hook)
            self._kernel_hook = None
        for lam in self.laminars.values():
            lam.stop()
        self.central.close()
        self.output.close()

    # ------------------------------ metrics ---------------------------- #
    def stats_snapshot(self):
        return self.stats.snapshot()

    def active_worker_counts(self) -> Dict[str, int]:
        return {
            name: sum(1 for w in lam.workers if w.activated)
            for name, lam in self.laminars.items()
        }

    @property
    def makespan(self) -> float:
        """Simulated-clock makespan (SimClock only)."""
        return getattr(self.clock, "makespan", 0.0)
