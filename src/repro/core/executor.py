"""AQPExecutor — wires EddyPull + EddyShardSet + Laminar routers + workers
into the executor of Fig. 2 and exposes the parent-executor pull interface
(a blocking iterator over the output queue).

Sharded routing core: the eddy loop runs as N shards over a lock-sharded
central queue with consumer-side work-stealing and merged statistics (see
core/eddy.py). Knobs:

  ``shards=None`` (default) — ONE shard, auto-scaling to ``SHARD_AUTO_MAX``
      once observed routing throughput crosses ``shard_auto_threshold``
      batches/s (the regime where routing, not UDF eval, is the ceiling).
      Under SimClock auto-scaling is disabled: the deterministic paths
      always run single-shard, bit-for-bit as before.
  ``shards=k`` — exactly k shards from the start (wall or sim clock).
  ``shard_auto_threshold`` — batches/s above which auto mode grows.

Resource arbitration (§5.2): the executor creates a ResourceArbiter (or
accepts a shared one) that owns every predicate's worker contexts and
leases device slots to the Laminar routers — scale-up keeps the queue
backpressure trigger, scale-down retires idle leases so capacity flows to
the current bottleneck predicate. Reallocation counters are exposed in
``stats_snapshot()`` under the reserved ``"_arbiter"`` key.

Micro-batch coalescing (§5.1): ``coalesce="adaptive" | "fixed" | k | off``
lets workers fuse queued same-predicate batches into one kernel launch,
amortizing per-launch overhead (see core/coalesce.py and
core/worker.evaluate_fused). Off by default — the deterministic SimClock
suites rely on one-launch-per-batch occupancy. Planner counters surface
in ``stats_snapshot()`` under the reserved ``"_coalesce"`` key.

Kernel cost visibility (§3.3): for the lifetime of a ``run()`` the executor
registers ``launch.connect_stats_board(self.stats, token=...)``, so every
Pallas launch a predicate makes reports its per-launch timing into the same
StatsBoard the routing policies rank on — kernel UDF cost is profiled, not
estimated, exactly like predicate-level cost. The hook is THREAD-AFFINE:
it is keyed by this executor's launch token, and every thread this executor
owns (eddy pull, eddy router, predicate workers) tags itself with that
token — so concurrent executors in one process each record only their own
launches (per-executor attribution; the old process-global bus
cross-recorded). The hook is removed in ``shutdown()`` so back-to-back
executors never double-count either.

FAILURE-SEMANTICS CONTRACT (``on_fault=``, core/faults.py):

* ``"fail_fast"`` (default): today's behavior bit-exact — the first
  worker exception aborts the query (``run()`` raises RuntimeError with
  the worker traceback); pull/shard errors raise as themselves.  Even on
  this path teardown is guaranteed: an errored batch decrements the
  in-flight tracker (no wedged termination barrier), a failed shard
  closes both queues so every blocked thread wakes immediately, and
  ``run()``'s finally / the context-manager ``__exit__`` route through
  ``shutdown()`` — launch hooks deregister and ``StatsStore.record_board``
  is still attempted.
* ``"retry"`` (or a ``FaultConfig``): per-batch retry with capped
  exponential backoff + seeded jitter (virtual delays under SimClock); a
  batch exhausting ``max_attempts`` completes as a conservative
  pass-through (rows kept, predicate flagged in ``batch.passthrough``);
  ``quarantine_after`` consecutive failures quarantine the predicate —
  the eddy skips it (logged) and routing ranks penalize flaky predicates
  by their error-rate EMA.
* ``"degrade"``: retry semantics plus automatic switch of a repeatedly-
  failing UDF to its reference path (``UDF.fallback_fn``) after
  ``degrade_after`` consecutive failures.
* ``fault_plan=`` injects deterministic faults (tests / bench_chaos);
  ``stats_snapshot()["_faults"]`` exposes the per-predicate ledger
  (failures, retries, error-rate EMA, quarantine/degraded state,
  pass-through counts, deadline hits, skipped routes — see
  ``FaultLedger.snapshot`` for the key contract).
* ``launch_deadline_s`` (FaultConfig): hung-launch detection — a
  wall-clock ``LaunchWatchdog`` thread flags in-flight launches past the
  deadline (it cannot preempt them; it makes routing see the hang), and
  under SimClock the deadline is checked post-hoc from virtual
  turnaround so deterministic timelines stay exact.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

from repro.core.batch import RoutingBatch
from repro.core.cache import ReuseCache
from repro.core.coalesce import COALESCE_QUEUE_CAPACITY, CoalesceConfig
from repro.core.eddy import (
    SHARD_AUTO_MAX, SHARD_AUTO_THRESHOLD_BPS, EddyPull, EddyShardSet,
    InFlightTracker,
)
from repro.core.faults import (
    FaultConfig, FaultLedger, LaunchWatchdog, ReverifyQueue,
)
from repro.core.laminar import GACU_MAX_WORKERS, LaminarRouter
from repro.core.policies import (
    ArbiterPolicy, EddyPolicy, HydroPolicy, LaminarPolicy, RoundRobin,
)
from repro.core.queues import CentralQueue, ClosedError
from repro.core.resources import DRAIN_THRESHOLD_S, DevicePool, ResourceArbiter
from repro.core.simclock import WallClock
from repro.core.stats import StatsBoard
from repro.core.statstore import StatsStore
from repro.core.udf import Predicate
from repro.kernels import launch as kernel_launch


class AQPExecutor:
    def __init__(
        self,
        predicates: List[Predicate],
        *,
        policy: Optional[EddyPolicy] = None,
        laminar_policy_factory=RoundRobin,
        clock=None,
        cache: Optional[ReuseCache] = None,
        central_capacity: int = 64,
        lam: float = 0.3,
        max_workers: int = GACU_MAX_WORKERS,
        devices: Optional[Dict[str, Sequence[str]]] = None,  # pred -> device groups
        serial_fraction: float = 0.0,
        warmup: bool = True,
        output_capacity: int = 1024,
        cost_alpha: float = 0.3,
        arbiter: Optional[ResourceArbiter] = None,
        pool: Optional[DevicePool] = None,
        arbiter_policy: Optional[ArbiterPolicy] = None,
        drain_threshold: Optional[float] = DRAIN_THRESHOLD_S,
        shards: Optional[int] = None,
        shard_auto_threshold: float = SHARD_AUTO_THRESHOLD_BPS,
        stats_store: Optional[StatsStore] = None,
        coalesce=None,
        worker_queue_capacity: Optional[int] = None,
        on_fault="fail_fast",
        fault_plan=None,
        query: Optional[str] = None,
        reverify: bool = False,
        virtual_drain: bool = False,
    ):
        self.predicates = predicates
        self.policy = policy or HydroPolicy()
        self.clock = clock or WallClock()
        self.cache = cache
        # Shard-count resolution: explicit ``shards=k`` wins; the default
        # is one shard that AUTO-scales to SHARD_AUTO_MAX above the
        # throughput threshold — except under SimClock, where the
        # deterministic timelines require the single-shard loop.
        if shards is not None and shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        deterministic = getattr(self.clock, "simulated", False)
        self._shard_auto = shards is None and not deterministic
        self._initial_shards = 1 if shards is None else shards
        self._max_shards = (
            SHARD_AUTO_MAX if self._shard_auto else self._initial_shards
        )
        self._shard_auto_threshold = shard_auto_threshold
        self.stats = StatsBoard(
            [p.name for p in predicates], cost_alpha=cost_alpha,
            shards=self._max_shards,
        )
        # Cross-query statistics (core/statstore.py): warm-start this
        # run's board from profiled, age-decayed records — a fully seeded
        # board skips the warmup circulation — and record the board back
        # (seed-only entries excluded) when the executor shuts down.
        self.stats_store = stats_store
        self._stats_seeded = (
            stats_store.warm_start(self.stats, predicates)
            if stats_store is not None else {}
        )
        self._stats_recorded = False
        self.central = CentralQueue(central_capacity, lam,
                                    shards=self._max_shards)
        self.output = CentralQueue(output_capacity, lam=1.0,
                                   shards=self._max_shards)
        self._error_lock = threading.Lock()
        self._worker_error = None
        # Fault tolerance (core/faults.py; module docstring contract):
        # fail_fast resolves to config None — workers take the
        # pre-fault-tolerance path byte-for-byte, and the ledger stays
        # clean (rank penalty exactly 1.0). The injection plan applies
        # regardless of mode (fail_fast + plan == "assert today's abort").
        self.fault_config = FaultConfig.resolve(on_fault)
        self.fault_plan = fault_plan
        self.faults = FaultLedger(
            [p.name for p in predicates],
            seed=self.fault_config.seed if self.fault_config else 0,
            probe_after_skips=(
                self.fault_config.probe_after_skips
                if self.fault_config else None
            ),
        )
        self.stats.faults = self.faults
        # Multi-tenancy (launch/serve.py): the query identity tags this
        # executor's registrations in a shared arbiter; service_info is
        # filled in by a managing QueryService and surfaced under the
        # stats_snapshot() "_service" key.
        self.query = query
        self.service_info: Optional[Dict[str, object]] = None
        # Re-verification queue (core/faults.py): with reverify=True the
        # run loop holds pass-through-flagged output batches and drains
        # them back through each flagged predicate once it recovers;
        # unrecovered flags release as-is at end of run.
        self.reverify_queue = (
            ReverifyQueue(predicates, self.faults,
                          fault_plan=self.fault_plan, clock=self.clock)
            if reverify else None
        )
        self._watchdog = None
        if (self.fault_config is not None
                and self.fault_config.launch_deadline_s is not None
                and not deterministic):
            # wall clock only: under SimClock deadline detection is
            # post-hoc from virtual turnaround (evaluate_resilient)
            self._watchdog = LaunchWatchdog(
                self.fault_config.launch_deadline_s,
                on_deadline=lambda name, elapsed:
                    self.faults.note_deadline(name),
            )
        # ONE tracker for the executor's lifetime: worker contexts hold a
        # reference (to decrement for batches dropped on error paths), so
        # run() must not swap in a fresh instance. Executors are
        # effectively one-shot (shutdown closes the queues), so there is
        # no carry-over between runs to worry about.
        self._tracker = InFlightTracker()
        # per-executor launch attribution token: every thread this executor
        # owns tags itself with it, and the run()-lifetime stats hook only
        # observes launches from so-tagged threads
        self._launch_token = object()
        # shared arbiter > shared pool > private unbounded pool (the
        # private default reproduces the pre-arbiter per-predicate pools)
        if arbiter is not None and (pool is not None or arbiter_policy is not None):
            raise ValueError(
                "pass either a pre-built arbiter OR pool/arbiter_policy "
                "(a shared arbiter keeps its own pool and policy)"
            )
        self.arbiter = arbiter or ResourceArbiter(
            pool=pool, policy=arbiter_policy
        )
        # Micro-batch coalescing knob (core/coalesce.py): off (default) |
        # "fixed"/int k | "adaptive". OFF is load-bearing for the
        # deterministic SimClock suites — their timelines are pinned to
        # one-launch-per-batch occupancy. When on, worker queues deepen to
        # COALESCE_QUEUE_CAPACITY by default so there is something to fuse
        # (an explicit worker_queue_capacity always wins).
        self.coalesce_config = CoalesceConfig.resolve(coalesce)
        if worker_queue_capacity is None:
            worker_queue_capacity = (
                COALESCE_QUEUE_CAPACITY if self.coalesce_config is not None
                else 2
            )
        pred_devices = {
            p.name: tuple((devices or {}).get(p.name, (p.resource,)))
            for p in predicates
        }
        self._check_pool_floors(pred_devices)
        self.laminars: Dict[str, LaminarRouter] = {}
        try:
            for p in predicates:
                self.laminars[p.name] = LaminarRouter(
                    p,
                    self.central,
                    self.stats,
                    cache=cache,
                    clock=self.clock,
                    policy=laminar_policy_factory(),
                    max_workers=max_workers,
                    devices=pred_devices[p.name],
                    serial_fraction=serial_fraction,
                    on_error=self._on_worker_error,
                    arbiter=self.arbiter,
                    drain_threshold=drain_threshold,
                    virtual_drain=virtual_drain,
                    query=query,
                    launch_token=self._launch_token,
                    coalesce=self.coalesce_config,
                    worker_queue_capacity=worker_queue_capacity,
                    fault_plan=self.fault_plan,
                    fault_ledger=self.faults,
                    fault_config=self.fault_config,
                    watchdog=self._watchdog,
                    tracker=self._tracker,
                )
        except BaseException:
            # don't poison a shared arbiter with half a registration: the
            # names registered before the failure must become reusable
            for name in self.laminars:
                self.arbiter.unregister(name)
            raise
        self.warmup = warmup
        self._pull: Optional[EddyPull] = None
        self._router: Optional[EddyShardSet] = None
        self._kernel_hook = None  # launch-timing hook, live only during run()

    # ------------------------------------------------------------------ #
    def _check_pool_floors(self, pred_devices: Dict[str, Sequence[str]]) -> None:
        """Fail fast on a pool that can never hold one floor slot per
        predicate: floor leases never retire, so an undersized BOUNDED
        pool is a guaranteed mid-query starvation, not a transient."""
        cap = self.arbiter.pool.capacity_of
        groups = {g for ds in pred_devices.values() for g in ds}
        if any(cap(g) is None for g in groups):
            return  # an unbounded group can absorb any floor demand
        total = sum(cap(g) for g in groups)
        if total < len(pred_devices):
            raise ValueError(
                f"DevicePool holds {total} slot(s) across {sorted(groups)} "
                f"but {len(pred_devices)} predicates each need a one-worker "
                "floor: the query would starve — size the pool to at least "
                "one slot per predicate"
            )
        for g in groups:  # predicates pinned to a single group
            pinned = [n for n, ds in pred_devices.items() if set(ds) == {g}]
            if len(pinned) > cap(g):
                raise ValueError(
                    f"device group {g!r} has {cap(g)} slot(s) but "
                    f"{len(pinned)} predicates ({sorted(pinned)}) can only "
                    "run there: the query would starve"
                )

    def _on_worker_error(self, exc, tb):
        with self._error_lock:
            if self._worker_error is None:
                self._worker_error = (exc, tb)
        self.output.close()
        self.central.close()

    def run(self, source: Iterable[RoutingBatch]) -> Iterator[RoutingBatch]:
        """Execute; yields completed (non-empty) batches in completion order."""
        if self._kernel_hook is None:
            # Per-launch kernel timings feed the routing StatsBoard for the
            # duration of the run — thread-affine on this executor's token,
            # so a concurrently-running executor never cross-records.
            # shutdown() deregisters.
            self._kernel_hook = kernel_launch.connect_stats_board(
                self.stats, token=self._launch_token
            )
        if self._watchdog is not None:
            self._watchdog.start()
        tracker = self._tracker
        self._pull = EddyPull(source, self.central,
                              launch_token=self._launch_token,
                              tracker=tracker)
        self._router = EddyShardSet(
            self.predicates, self.central, self.output, self.laminars,
            self.stats, self.policy, self._pull,
            cache=self.cache, warmup=self.warmup,
            launch_token=self._launch_token,
            shards=self._initial_shards,
            max_shards=self._max_shards,
            auto_threshold=self._shard_auto_threshold,
            tracker=tracker,
            faults=self.faults,
        )
        self._pull.start()
        self._router.start()
        try:
            while True:
                try:
                    out = self.output.get(timeout=1.0)
                except TimeoutError:
                    if self._worker_error is not None:
                        break
                    continue
                except ClosedError:
                    break
                if self.reverify_queue is None:
                    yield out
                    continue
                # re-verification (core/faults.py): flagged batches are
                # held; recovered predicates' holds drain opportunistically
                out = self.reverify_queue.offer(out)
                if out is not None:
                    yield out
                if self.reverify_queue.pending():
                    for b in self.reverify_queue.drain():
                        yield b
            if self.reverify_queue is not None:
                # end of run: release still-held batches — re-verified
                # where the predicate recovered, still-flagged otherwise
                # (the pre-reverify conservative contract)
                for b in self.reverify_queue.drain(force=True):
                    yield b
        finally:
            self.shutdown()
        if self._worker_error is not None:
            exc, tb = self._worker_error
            raise RuntimeError(f"predicate worker failed:\n{tb}") from exc
        if self._pull.error is not None:
            raise self._pull.error
        if self._router.error is not None:
            raise self._router.error

    def collect(self, source: Iterable[RoutingBatch]) -> List[RoutingBatch]:
        return list(self.run(source))

    # ------------------------- context manager ------------------------- #
    # ``with AQPExecutor(...) as ex:`` guarantees teardown on EVERY exit
    # path — including a consumer that abandons the run() generator
    # mid-iteration, where the generator's own finally-clause only fires
    # at GC time. shutdown() is idempotent, so run()'s internal teardown
    # composing with __exit__ is harmless.
    def __enter__(self) -> "AQPExecutor":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown()

    def shutdown(self) -> None:
        if self._watchdog is not None:
            self._watchdog.stop()
        if self._kernel_hook is not None:
            kernel_launch.remove_launch_hook(self._kernel_hook)
            self._kernel_hook = None
        for lam in self.laminars.values():
            lam.stop()
        self.central.close()
        self.output.close()
        if self.stats_store is not None and not self._stats_recorded:
            self._stats_recorded = True
            try:
                self.stats_store.record_board(
                    self.stats, self.predicates, seeded=self._stats_seeded
                )
                self.stats_store.flush()
            except Exception as e:
                # persistence is best-effort at teardown: a full disk or
                # yanked mount must not mask the query's actual results
                import warnings

                warnings.warn(f"StatsStore persistence failed: {e!r}")

    # ------------------------------ metrics ---------------------------- #
    def stats_snapshot(self):
        """Predicate statistics plus arbiter and routing-core counters.

        Predicate entries are keyed by name as before; the reserved
        ``"_arbiter"`` key carries lease/release/denial/handoff counters,
        ``"_routing"`` the shard-set picture (active shards, steals,
        circulations, completed), and ``"_faults"`` the per-predicate
        fault ledger (see core/faults.FaultLedger.snapshot for the key
        contract). The reserved ``"_service"`` key carries the
        multi-tenant picture: ``{"managed": False}`` for a standalone
        executor, or the managing QueryService's per-query identity
        (query id, priority, deadline — see launch/serve.py) when this
        executor runs as a service tenant; with ``reverify=True`` it also
        carries the re-verification counters
        (``ReverifyQueue.snapshot``). Consumers iterating predicate
        entries should skip ``_``-keys."""
        snap = self.stats.snapshot()
        snap["_arbiter"] = self.arbiter.counters()
        snap["_faults"] = self.faults.snapshot()
        svc: Dict[str, object] = (
            dict(self.service_info) if self.service_info
            else {"managed": False}
        )
        if self.reverify_queue is not None:
            svc["reverify"] = self.reverify_queue.snapshot()
        snap["_service"] = svc
        r = self._router
        snap["_routing"] = {
            "shards_active": r.shards_active if r is not None else 0,
            "steals": r.steals if r is not None else 0,
            "circulations": r.circulations if r is not None else 0,
            "completed": r.completed if r is not None else 0,
        }
        if self.coalesce_config is not None:
            snap["_coalesce"] = {
                "mode": self.coalesce_config.mode,
                **{
                    name: lam.coalesce_planner.counters()
                    for name, lam in self.laminars.items()
                    if lam.coalesce_planner is not None
                },
            }
        return snap

    @property
    def shards_active(self) -> int:
        """Routing shards currently running (grows past 1 only when
        auto-scaling trips or ``shards=`` was explicit)."""
        return self._router.shards_active if self._router is not None else 0

    def active_worker_counts(self) -> Dict[str, int]:
        return {
            name: sum(1 for w in lam.workers if w.activated)
            for name, lam in self.laminars.items()
        }

    def leased_worker_counts(self) -> Dict[str, int]:
        """Current leases per predicate (the §5.2 allocation picture)."""
        return {
            name: len(lam.active_workers)
            for name, lam in self.laminars.items()
        }

    @property
    def makespan(self) -> float:
        """Simulated-clock makespan (SimClock only)."""
        return getattr(self.clock, "makespan", 0.0)


class QuerySession:
    """Restartable per-query session over a (possibly shared) arbiter.

    ``AQPExecutor`` is one-shot by design: ``shutdown()`` closes its
    queues, so a second ``run()`` on the same instance cannot work. A
    ``QuerySession`` is the restartable object the multi-tenant service
    holds instead: it captures the predicates and executor configuration
    once, and every ``run()`` builds a FRESH executor, streams its
    output, and GUARANTEES teardown (context-manager + finally) even if
    the consumer abandons the iterator or an evaluation fails — the
    arbiter registration is released, so the same predicate names are
    re-registerable for the next run and the shared DevicePool never
    leaks slots. The final ``stats_snapshot()`` of each run is kept in
    ``last_snapshot`` for telemetry."""

    def __init__(self, predicates: List[Predicate], **executor_kwargs):
        self.predicates = predicates
        self.executor_kwargs = executor_kwargs
        self.runs = 0
        self.executor: Optional[AQPExecutor] = None  # live during run()
        self.last_snapshot = None

    def run(self, source: Iterable[RoutingBatch]) -> Iterator[RoutingBatch]:
        """One full query execution on a fresh executor; restartable."""
        ex = AQPExecutor(self.predicates, **self.executor_kwargs)
        self.executor = ex
        self.runs += 1
        try:
            with ex:
                for b in ex.run(source):
                    yield b
        finally:
            self.last_snapshot = ex.stats_snapshot()
            self.executor = None

    def collect(self, source: Iterable[RoutingBatch]) -> List[RoutingBatch]:
        return list(self.run(source))
