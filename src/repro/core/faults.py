"""Fault injection, per-predicate fault ledger, and launch watchdog.

Hydro's premise — UDF behavior is unknowable up front, so the plan must
adapt DURING execution — applies to failures as much as to cost: a flaky
compiled kernel, a hung launch, or a poison batch is just another runtime
statistic the eddy should route around.  This module supplies the three
pieces the AQP core wires together (see core/executor.py for the
end-to-end failure-semantics contract):

* ``FaultPlan`` — a deterministic injection API for tests and the chaos
  benchmark: fail launch N of predicate P, hang a launch for T (virtual or
  wall) seconds, corrupt an output's dtype.  Schedules are either explicit
  1-based attempt indices or seeded per-attempt Bernoulli draws; every
  random stream is derived from ``(plan seed, predicate name, spec
  index)``, so an injected timeline is bit-exact run to run and
  SimClock-compatible (an injected hang becomes extra VIRTUAL occupancy,
  never a wall sleep, under the simulated clock).

* ``FaultLedger`` — the per-predicate failure statistics the routing
  layer ranks on: error-rate EMA, consecutive-failure count, retry /
  quarantine / degradation / deadline counters.  Surfaced in
  ``AQPExecutor.stats_snapshot()["_faults"]``.  Writes happen only on the
  (rare) failure/retry/success bookkeeping path; the hot read
  (``rank_penalty``) is lock-free and returns exactly 1.0 until the first
  failure is recorded, so fault-free runs rank bit-identically to a build
  without this module.

* ``LaunchWatchdog`` — a wall-clock daemon thread (name prefix
  ``fault-watchdog``, covered by the tests/conftest.py leaked-thread
  guard) that flags in-flight launches older than a deadline.  Python
  cannot interrupt a thread blocked inside a foreign launch, so the
  watchdog's job is *visibility*: the ledger learns about the hang WHILE
  it is in progress, and failure-aware routing steers new batches away
  from the wedged predicate instead of piling onto it.  Under SimClock
  deadlines are checked post-hoc from virtual turnaround instead (the
  watchdog thread never starts), keeping deterministic timelines exact.
"""
from __future__ import annotations

import itertools
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core.stats import Ema

# Error-rate -> rank-penalty slope: a predicate failing every launch
# (EMA -> 1.0) ranks as if it cost (1 + weight)x its measured cost, so the
# eddy defers it behind healthy siblings without ever starving it outright
# (quarantine, not penalty, is what removes a predicate from routing).
FAULT_PENALTY_WEIGHT = 4.0

# Error-rate EMA horizon: ~the last dozen evaluations dominate, so a
# predicate that recovers stops paying the penalty within a few batches.
FAULT_EMA_ALPHA = 0.15


class InjectedFault(RuntimeError):
    """A deliberately injected launch failure (FaultPlan ``fail`` spec)."""


class CorruptOutputError(RuntimeError):
    """An evaluation returned outputs violating the UDF's learned spec
    (wrong leading row count, or — under injection — wrong dtype)."""


def _spec_rng(seed: int, pred: str, index: int) -> np.random.Generator:
    """Deterministic per-(plan, predicate, spec) random stream."""
    return np.random.default_rng((seed, zlib.crc32(pred.encode()), index))


@dataclass
class FaultSpec:
    """One injection rule: WHAT happens on WHICH attempts of WHICH predicate.

    ``attempts`` are 1-based indices into the predicate's global attempt
    counter (retries count as new attempts); ``probability`` instead draws
    a seeded Bernoulli per attempt.  ``compiled_only`` specs stop firing
    once the predicate has been degraded to its reference path — modelling
    a fault in the COMPILED executable that the fallback escapes."""

    pred: str
    kind: str                      # "error" | "hang" | "corrupt"
    attempts: Tuple[int, ...] = ()
    probability: float = 0.0
    hang_s: float = 0.0
    compiled_only: bool = True
    rng: Optional[np.random.Generator] = None

    def triggers(self, attempt: int) -> bool:
        if self.attempts:
            return attempt in self.attempts
        if self.probability > 0.0 and self.rng is not None:
            # one draw per attempt, unconditionally: the stream position
            # depends only on the attempt index, never on other specs
            return bool(self.rng.random() < self.probability)
        return False


class FaultPlan:
    """Deterministic fault schedule for a set of predicates.

    Chainable builders::

        plan = (FaultPlan(seed=7)
                .fail("detector", attempts=(1, 2))      # first two launches
                .fail("classifier", probability=0.05)   # seeded 5%/launch
                .hang("ocr", attempts=(3,), seconds=2)  # 3rd launch stalls
                .corrupt("detector", attempts=(5,)))    # wrong dtype once

    ``invoke`` wraps ``pred.evaluate_outputs`` and is the ONLY seam the
    worker needs: errors raise ``InjectedFault`` before any virtual cost
    accrues (an injected failure is pre-launch in the simulated timeline;
    wall-clock failures cost whatever real time elapsed), hangs sleep for
    real under a wall clock or deposit extra virtual occupancy consumed by
    ``take_extra_cost`` under SimClock, and corruptions cast the real
    output to ``complex128`` so the worker-side spec validation trips."""

    def __init__(self, *, seed: int = 0):
        self.seed = seed
        self._specs: Dict[str, list] = {}
        self._attempts: Dict[str, int] = {}
        self._count = itertools.count()
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.injected = 0

    # ------------------------- builders ------------------------- #
    def _add(self, pred: str, kind: str, attempts: Sequence[int],
             probability: float, hang_s: float = 0.0) -> "FaultPlan":
        spec = FaultSpec(pred=pred, kind=kind, attempts=tuple(attempts),
                         probability=float(probability), hang_s=hang_s)
        if spec.probability > 0.0:
            spec.rng = _spec_rng(self.seed, pred, next(self._count))
        else:
            next(self._count)  # keep downstream spec streams stable
        self._specs.setdefault(pred, []).append(spec)
        return self

    def fail(self, pred: str, *, attempts: Sequence[int] = (),
             probability: float = 0.0) -> "FaultPlan":
        return self._add(pred, "error", attempts, probability)

    def hang(self, pred: str, *, attempts: Sequence[int] = (),
             probability: float = 0.0, seconds: float = 1.0) -> "FaultPlan":
        return self._add(pred, "hang", attempts, probability, hang_s=seconds)

    def corrupt(self, pred: str, *, attempts: Sequence[int] = (),
                probability: float = 0.0) -> "FaultPlan":
        return self._add(pred, "corrupt", attempts, probability)

    # ------------------------- injection ------------------------- #
    def attempt_count(self, pred: str) -> int:
        with self._lock:
            return self._attempts.get(pred, 0)

    def take_extra_cost(self) -> float:
        """Pending injected-hang VIRTUAL seconds for the calling thread
        (set by ``invoke`` under SimClock, consumed by the worker's
        occupancy accounting; always 0.0 under a wall clock)."""
        extra = getattr(self._tls, "extra", 0.0)
        self._tls.extra = 0.0
        return extra

    def invoke(self, pred, data, clock) -> np.ndarray:
        """Evaluate ``pred`` on ``data`` with this plan's faults applied."""
        degraded = getattr(pred.udf, "degraded", False)
        with self._lock:
            attempt = self._attempts.get(pred.name, 0) + 1
            self._attempts[pred.name] = attempt
            fired = None
            for spec in self._specs.get(pred.name, ()):
                hit = spec.triggers(attempt)
                if hit and fired is None \
                        and not (spec.compiled_only and degraded):
                    fired = spec
        if fired is None:
            return pred.evaluate_outputs(data)
        self.injected += 1
        if fired.kind == "error":
            raise InjectedFault(
                f"injected failure: {pred.name} attempt {attempt}"
            )
        if fired.kind == "hang":
            if getattr(clock, "simulated", False):
                # virtual hang: extra occupancy, consumed by the worker's
                # SimClock cost accounting — bit-exact, no wall sleep
                self._tls.extra = getattr(self._tls, "extra", 0.0) \
                    + fired.hang_s
            else:
                time.sleep(fired.hang_s)
            return pred.evaluate_outputs(data)
        # corrupt: run the real evaluation, hand back a wrong dtype — the
        # worker's output-spec validation must catch it BEFORE caching
        out = np.asarray(pred.evaluate_outputs(data))
        return out.astype(np.complex128)


@dataclass(frozen=True)
class FaultConfig:
    """Retry/degradation policy for the worker evaluation loop.

    ``mode``: ``"retry"`` retries with capped exponential backoff and
    quarantines poison batches / repeatedly-failing predicates;
    ``"degrade"`` additionally switches a failing UDF to its reference
    path (``UDF.fallback_fn``) after ``degrade_after`` consecutive
    failures.  Backoff for attempt k is ``min(base * 2^(k-1), cap)``
    times a seeded jitter factor in ``[1, 1 + jitter]`` — under SimClock
    the delay advances the batch's VIRTUAL ready time (never a wall
    sleep).  ``launch_deadline_s`` arms deadline detection: post-hoc
    virtual turnaround under SimClock, the ``LaunchWatchdog`` thread
    under a wall clock.

    ``probe_after_skips`` (None = off, the pre-probe behavior: quarantine
    is permanent within a run) arms RECOVERY PROBES: after that many
    routing-level skips of a quarantined predicate, the eddy routes ONE
    probe batch to it (``FaultLedger.take_probe_route``).  The probe gets
    a single attempt — success un-quarantines the predicate
    (``clear_quarantine``) and normal routing resumes; failure re-arms
    the skip counter so the next probe waits another full window."""

    mode: str = "retry"
    max_attempts: int = 3
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.25
    jitter: float = 0.1
    seed: int = 0
    degrade_after: int = 2
    quarantine_after: int = 6
    launch_deadline_s: Optional[float] = None
    probe_after_skips: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("retry", "degrade"):
            raise ValueError(f"FaultConfig mode must be retry|degrade, "
                             f"got {self.mode!r}")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.probe_after_skips is not None and self.probe_after_skips < 1:
            raise ValueError("probe_after_skips must be >= 1 (or None)")

    @classmethod
    def resolve(cls, on_fault) -> Optional["FaultConfig"]:
        """``None``/``"fail_fast"`` -> None (the pre-fault-tolerance
        abort-on-first-error path, byte-for-byte); ``"retry"``/``"degrade"``
        -> defaults; a ``FaultConfig`` instance passes through."""
        if on_fault is None or on_fault == "fail_fast":
            return None
        if isinstance(on_fault, cls):
            return on_fault
        if on_fault in ("retry", "degrade"):
            return cls(mode=on_fault)
        raise ValueError(
            f"on_fault must be 'fail_fast', 'retry', 'degrade' or a "
            f"FaultConfig, got {on_fault!r}"
        )


def backoff_delay(config: FaultConfig, attempt: int,
                  rng: np.random.Generator) -> float:
    """Capped exponential backoff with seeded jitter for attempt N >= 1."""
    base = min(config.backoff_base_s * (2.0 ** (attempt - 1)),
               config.backoff_cap_s)
    if base <= 0.0:
        return 0.0
    if config.jitter > 0.0:
        base *= 1.0 + config.jitter * float(rng.random())
    return base


@dataclass
class PredicateFaultState:
    """One predicate's fault history (see ``FaultLedger.snapshot`` for the
    exported key contract)."""

    name: str
    failures: int = 0
    successes: int = 0
    retries: int = 0
    consecutive_failures: int = 0
    quarantined: bool = False
    degraded: bool = False
    quarantined_batches: int = 0
    quarantined_rows: int = 0
    deadline_hits: int = 0
    skipped_routes: int = 0
    # recovery-probe state machine (FaultConfig.probe_after_skips):
    # skips arm probe_pending -> the eddy claims it (take_probe_route,
    # probe_inflight) -> the worker claims the single attempt
    # (begin_probe) -> end_probe either clears the quarantine or re-arms
    # the skip window
    skips_since_probe: int = 0
    probe_pending: bool = False
    probe_inflight: bool = False
    probes: int = 0
    unquarantines: int = 0
    last_error: str = ""
    error_rate: Ema = field(
        default_factory=lambda: Ema(FAULT_EMA_ALPHA)
    )
    rng: Optional[np.random.Generator] = None


class FaultLedger:
    """Per-predicate fault statistics shared by workers and the eddy.

    Writes (note_*) take the ledger lock but only run on failure /
    bookkeeping paths; ``rank_penalty`` — called once per predicate per
    routing decision — is lock-free and short-circuits to exactly 1.0
    until the first failure is recorded, so a fault-free run's rank keys
    are bit-identical to a ledger-less build (x * 1.0 == x)."""

    def __init__(self, predicate_names: Iterable[str] = (), *, seed: int = 0,
                 probe_after_skips: Optional[int] = None):
        self.seed = seed
        self.probe_after_skips = probe_after_skips
        self._lock = threading.Lock()
        self._entries: Dict[str, PredicateFaultState] = {}
        # lock-free fast-path flags (GIL-atomic bool reads)
        self.dirty = False            # any failure ever recorded
        self.has_quarantined = False  # any predicate currently quarantined
        for n in predicate_names:
            self._entry(n)

    def _entry(self, name: str) -> PredicateFaultState:
        st = self._entries.get(name)
        if st is None:
            with self._lock:
                st = self._entries.get(name)
                if st is None:
                    st = PredicateFaultState(
                        name, rng=_spec_rng(self.seed, name, 0)
                    )
                    self._entries[name] = st
        return st

    def entry(self, name: str) -> PredicateFaultState:
        return self._entry(name)

    # ------------------------- recording ------------------------- #
    def note_failure(self, name: str, error: Optional[BaseException] = None
                     ) -> int:
        """Record one failed evaluation attempt; returns the consecutive-
        failure count (the degrade/quarantine trigger)."""
        st = self._entry(name)
        with self._lock:
            self.dirty = True
            st.failures += 1
            st.consecutive_failures += 1
            st.error_rate.update(1.0)
            if error is not None:
                st.last_error = repr(error)
            return st.consecutive_failures

    def note_success(self, name: str) -> None:
        st = self._entry(name)
        with self._lock:
            st.successes += 1
            st.consecutive_failures = 0
            st.error_rate.update(0.0)

    def note_retry(self, name: str) -> None:
        st = self._entry(name)
        with self._lock:
            st.retries += 1

    def note_quarantined_batch(self, name: str, rows: int) -> None:
        st = self._entry(name)
        with self._lock:
            st.quarantined_batches += 1
            st.quarantined_rows += int(rows)

    def note_degraded(self, name: str) -> None:
        st = self._entry(name)
        with self._lock:
            st.degraded = True

    def note_deadline(self, name: str) -> None:
        st = self._entry(name)
        with self._lock:
            self.dirty = True
            st.deadline_hits += 1

    def note_skip(self, name: str) -> None:
        st = self._entry(name)
        with self._lock:
            st.skipped_routes += 1
            if (self.probe_after_skips is not None and st.quarantined
                    and not st.probe_pending and not st.probe_inflight):
                st.skips_since_probe += 1
                if st.skips_since_probe >= self.probe_after_skips:
                    st.probe_pending = True
                    st.skips_since_probe = 0

    def set_quarantined(self, name: str) -> bool:
        """Quarantine ``name``; returns True if newly quarantined."""
        st = self._entry(name)
        with self._lock:
            if st.quarantined:
                return False
            st.quarantined = True
            st.skips_since_probe = 0
            st.probe_pending = False
            st.probe_inflight = False
            self.has_quarantined = True
            return True

    def clear_quarantine(self, name: str) -> bool:
        """Lift ``name``'s quarantine (probe success); returns True if it
        was quarantined.  Resets the consecutive-failure streak so the
        next real failure starts a fresh window rather than instantly
        re-quarantining."""
        st = self._entry(name)
        with self._lock:
            if not st.quarantined:
                return False
            st.quarantined = False
            st.consecutive_failures = 0
            st.skips_since_probe = 0
            st.probe_pending = False
            st.probe_inflight = False
            st.unquarantines += 1
            self.has_quarantined = any(
                s.quarantined for s in self._entries.values()
            )
            return True

    # ------------------------- recovery probes ------------------------- #
    def take_probe_route(self, name: str) -> bool:
        """Eddy-side claim: route ONE batch to quarantined ``name`` as a
        recovery probe instead of skipping it.  At most one probe is in
        flight per predicate; returns True exactly once per armed probe."""
        st = self._entry(name)
        with self._lock:
            if not (st.quarantined and st.probe_pending):
                return False
            st.probe_pending = False
            st.probe_inflight = True
            st.probes += 1
            return True

    def begin_probe(self, name: str) -> bool:
        """Worker-side claim of the in-flight probe: the caller must give
        the quarantined predicate exactly ONE evaluation attempt (no
        retries) and report the outcome via ``end_probe``.  Returns False
        for any non-probe batch that raced into a quarantined predicate's
        queue (those pass through as before)."""
        st = self._entry(name)
        with self._lock:
            if not st.probe_inflight:
                return False
            st.probe_inflight = False
            return True

    def end_probe(self, name: str, success: bool) -> bool:
        """Probe outcome: success lifts the quarantine (returns True);
        failure re-arms the skip window so the next probe waits another
        full ``probe_after_skips`` skips."""
        if success:
            return self.clear_quarantine(name)
        st = self._entry(name)
        with self._lock:
            st.skips_since_probe = 0
            st.probe_pending = False
            st.probe_inflight = False
            return False

    # ------------------------- reading ------------------------- #
    def is_quarantined(self, name: str) -> bool:
        if not self.has_quarantined:
            return False
        st = self._entries.get(name)
        return st is not None and st.quarantined

    def quarantined_names(self) -> Tuple[str, ...]:
        if not self.has_quarantined:
            return ()
        with self._lock:
            return tuple(
                n for n, st in self._entries.items() if st.quarantined
            )

    def failed_names(self) -> Tuple[str, ...]:
        """Predicates with at least one recorded failure.  The eddy exempts
        these from the warmup all-measured gate: a failing predicate may
        never produce a measurement, and warmup dispatches one batch per
        predicate exactly once — waiting on it would circulate every other
        batch forever.  (Exempt, not skipped: normal ranking still routes
        batches to it, so it either recovers and gets measured or keeps
        failing until quarantine removes it.)"""
        if not self.dirty:
            return ()
        with self._lock:
            return tuple(
                n for n, st in self._entries.items() if st.failures > 0
            )

    def error_rate_of(self, name: str) -> float:
        st = self._entries.get(name)
        return 0.0 if st is None else st.error_rate.get(0.0)

    def rank_penalty(self, name: str) -> float:
        """Routing rank multiplier: exactly 1.0 for a never-failed
        predicate (bit-exact fault-free ranking), growing linearly in the
        error-rate EMA for a flaky one."""
        if not self.dirty:
            return 1.0
        st = self._entries.get(name)
        if st is None:
            return 1.0
        rate = st.error_rate.get(0.0)
        return 1.0 if rate <= 0.0 else 1.0 + FAULT_PENALTY_WEIGHT * rate

    def jitter_rng(self, name: str) -> np.random.Generator:
        return self._entry(name).rng

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Exported under ``stats_snapshot()["_faults"]``.  Per predicate:

        failures / successes / retries — attempt-level counters;
        consecutive_failures — current streak (degrade/quarantine trigger);
        error_rate — failure-probability EMA (the routing rank penalty);
        quarantined / degraded — current state flags;
        quarantined_batches / quarantined_rows — poison batches completed
        via the conservative pass-through verdict;
        deadline_hits — launches past ``launch_deadline_s``;
        skipped_routes — routing decisions that skipped this predicate
        because it was quarantined;
        probes — recovery probes routed (``probe_after_skips`` armed);
        unquarantines — quarantines lifted by a probe success;
        last_error — repr of the most recent failure."""
        with self._lock:
            return {
                n: {
                    "failures": st.failures,
                    "successes": st.successes,
                    "retries": st.retries,
                    "consecutive_failures": st.consecutive_failures,
                    "error_rate": st.error_rate.get(0.0),
                    "quarantined": st.quarantined,
                    "degraded": st.degraded,
                    "quarantined_batches": st.quarantined_batches,
                    "quarantined_rows": st.quarantined_rows,
                    "deadline_hits": st.deadline_hits,
                    "skipped_routes": st.skipped_routes,
                    "probes": st.probes,
                    "unquarantines": st.unquarantines,
                    "last_error": st.last_error,
                }
                for n, st in self._entries.items()
            }


class LaunchWatchdog:
    """Flags in-flight launches older than ``deadline_s`` (wall clock).

    ``begin``/``end`` bracket a launch (called by the worker retry loop
    and the ``kernels.launch`` pallas_call wrapper via
    ``set_launch_watchdog``); a daemon scan thread (name
    ``fault-watchdog``, guarded by the conftest leaked-thread check)
    flags each overdue launch exactly once through ``on_deadline(name,
    elapsed)``.  It cannot preempt the hung launch — Python can't
    interrupt a thread blocked in a foreign call — the point is that the
    fault ledger learns about the hang while it is still in progress, so
    routing steers new work away instead of stacking onto the wedged
    worker.  ``scan`` is callable directly (with an explicit ``now``) for
    deterministic tests; ``start`` is optional."""

    def __init__(self, deadline_s: float,
                 on_deadline: Callable[[str, float], None],
                 *, interval_s: Optional[float] = None):
        self.deadline_s = float(deadline_s)
        self.on_deadline = on_deadline
        self.interval_s = interval_s or max(self.deadline_s / 4.0, 0.01)
        self._inflight: Dict[int, list] = {}  # token -> [name, start, flagged]
        self._count = itertools.count(1)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.began = 0
        self.flagged = 0

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="fault-watchdog"
        )
        self._thread.start()

    def begin(self, name: str) -> int:
        token = next(self._count)
        with self._lock:
            self._inflight[token] = [name, time.monotonic(), False]
            self.began += 1
        return token

    def end(self, token: int) -> None:
        with self._lock:
            self._inflight.pop(token, None)

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def scan(self, now: Optional[float] = None) -> int:
        """Flag overdue launches once; returns how many were flagged."""
        now = time.monotonic() if now is None else now
        overdue = []
        with self._lock:
            for entry in self._inflight.values():
                name, start, seen = entry
                elapsed = now - start
                if not seen and elapsed > self.deadline_s:
                    entry[2] = True
                    self.flagged += 1
                    overdue.append((name, elapsed))
        for name, elapsed in overdue:
            try:
                self.on_deadline(name, elapsed)
            except Exception:
                pass  # observability must never take down the scan thread
        return len(overdue)

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.scan()

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=2.0)
            self._thread = None


class ReverifyQueue:
    """Drains conservative pass-through verdicts once a predicate recovers.

    Quarantine / poison-batch completion keeps every row (flagged in
    ``batch.passthrough``) so the termination barrier and row-id-multiset
    invariants hold — but the flagged rows were never actually FILTERED by
    the flagged predicate.  With the executor knob ``reverify=True`` the
    run loop intercepts flagged output batches here instead of emitting
    them; ``drain()`` re-evaluates each held batch through every flagged
    predicate that has since RECOVERED (not quarantined, current streak
    clean, at least one recorded success — e.g. after a probe
    un-quarantine), clears the flag (``batch.clear_passthrough``), and
    applies the real row filter.  A predicate that never recovers within
    the run releases its batches still-flagged at the final forced drain,
    preserving the conservative contract.

    Re-verification runs on the executor's OWN thread (offer/drain are
    called from the run loop, never from workers) and deliberately
    bypasses the cache and SimClock occupancy: it is an audit path, not
    the measured hot path, and the held batches already completed —
    re-evaluation must not perturb pinned virtual timelines.  Failures
    during re-verification are recorded in the ledger like any other
    attempt and leave the flag in place."""

    def __init__(self, predicates, ledger: FaultLedger,
                 *, fault_plan: Optional[FaultPlan] = None,
                 clock: Optional[object] = None):
        self._preds = {p.name: p for p in predicates}
        self.ledger = ledger
        self.fault_plan = fault_plan
        self.clock = clock
        self._held: list = []
        self._lock = threading.Lock()
        self.intercepted = 0
        self.reverified_batches = 0
        self.reverified_rows = 0
        self.dropped_rows = 0
        self.released_flagged = 0

    def offer(self, batch):
        """Intercept ``batch`` if it carries pass-through flags; returns
        the batch unchanged when clean, None when held for re-verify."""
        if not batch.passthrough:
            return batch
        with self._lock:
            self._held.append(batch)
            self.intercepted += 1
        return None

    def pending(self) -> int:
        with self._lock:
            return len(self._held)

    def _recovered(self, name: str) -> bool:
        st = self.ledger.entry(name)
        with self.ledger._lock:
            return (not st.quarantined and st.consecutive_failures == 0
                    and st.successes > 0)

    def _reverify_one(self, pred, batch):
        """One single-attempt re-evaluation; None on failure (flag kept)."""
        data = {c: batch.data[c] for c in pred.udf.columns}
        try:
            if self.fault_plan is not None:
                outputs = self.fault_plan.invoke(pred, data, self.clock)
                self.fault_plan.take_extra_cost()  # discard virtual hangs
            else:
                outputs = pred.evaluate_outputs(data)
            out = np.asarray(outputs)
            if out.ndim == 0 or out.shape[0] != batch.rows:
                raise CorruptOutputError(
                    f"{pred.name}: reverify expected {batch.rows} output "
                    f"rows, got shape {out.shape}"
                )
        except Exception as e:
            self.ledger.note_failure(pred.name, error=e)
            return None
        self.ledger.note_success(pred.name)
        mask = pred.mask_from_outputs(out)
        refined = batch.clear_passthrough(pred.name).filter(mask)
        self.reverified_rows += batch.rows
        self.dropped_rows += batch.rows - refined.rows
        return refined

    def drain(self, *, force: bool = False) -> list:
        """Re-verify held batches whose flagged predicates recovered.

        Returns the batches ready for release: fully re-verified ones
        (flags cleared, rows filtered) and — under ``force=True``, the
        end-of-run flush — still-flagged batches released as-is (the
        pre-reverify conservative contract).  Batches with unrecovered
        flags stay held unless forced."""
        with self._lock:
            held, self._held = self._held, []
        out, keep = [], []
        for batch in held:
            for name in sorted(batch.passthrough):
                pred = self._preds.get(name)
                if pred is None or not self._recovered(name):
                    continue
                refined = self._reverify_one(pred, batch)
                if refined is not None:
                    batch = refined
                    self.reverified_batches += 1
            if batch.passthrough and not force:
                keep.append(batch)
            else:
                if batch.passthrough:
                    self.released_flagged += 1
                out.append(batch)
        if keep:
            with self._lock:
                self._held = keep + self._held
        return out

    def snapshot(self) -> Dict[str, int]:
        """Exported under ``stats_snapshot()["_service"]`` /
        per-query telemetry."""
        with self._lock:
            return {
                "pending": len(self._held),
                "intercepted": self.intercepted,
                "reverified_batches": self.reverified_batches,
                "reverified_rows": self.reverified_rows,
                "dropped_rows": self.dropped_rows,
                "released_flagged": self.released_flagged,
            }
